"""The trace-based simulator must reproduce the paper's qualitative Table I:
RingAda < PipeAdapter < Single on both time and memory — and the packed
Phase-A conveyor's closed-form tick counts (``S*M + F - 1`` per round,
``(S-1)*(F-1)`` saved vs the per-owner scan) must fall out of the
discrete-event engine, not just the formula."""

import pytest

from repro.core.partition import DeviceProfile
from repro.core.pipeline import pipeline_tick_counts
from repro.core.simulator import (LayerProfile, SimConfig, simulate_round,
                                  simulate_training)


def _layers(n=12):
    return [LayerProfile(fwd_s=0.01, bwd_s=0.02, act_mb=20.0, weight_mb=30.0,
                         adapter_mb=0.6, boundary_mb=2.0)] * n


def _devices(u=4):
    return [DeviceProfile(compute_speed=1.0, memory_mb=4096,
                          link_mbps=1000.0)] * u


def test_single_vs_pipeline_time():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8,
                    head_fwd_s=0.002, head_bwd_s=0.004, head_mb=50, embed_mb=50)
    r_single = simulate_round("single", sim, _layers(), _devices())
    r_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices())
    assert r_pipe.time_per_round_s < r_single.time_per_round_s


def test_ringada_faster_than_pipeadapter_when_frozen():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    r_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices())
    r_ring = simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=3)
    assert r_ring.time_per_round_s < r_pipe.time_per_round_s


def test_memory_ordering_matches_table1():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8,
                    head_mb=50, embed_mb=50)
    m_single = simulate_round("single", sim, _layers(), _devices()
                              ).max_memory_mb
    m_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices()
                            ).max_memory_mb
    m_ring = simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=3).max_memory_mb
    assert m_ring < m_pipe < m_single


def test_deeper_unfreezing_costs_more():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    times = [simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=d).time_per_round_s
             for d in (1, 6, 12)]
    assert times[0] < times[1] <= times[2]


def test_training_schedule_integration():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    t_ring, m_ring, curve = simulate_training(
        "ringada", sim, _layers(), _devices(), rounds=50,
        unfreeze_interval=10)
    t_pipe, m_pipe, _ = simulate_training(
        "pipe_adapter", sim, _layers(), _devices(), rounds=50)
    assert t_ring < t_pipe
    assert m_ring < m_pipe
    assert len(curve) == 50 and curve == sorted(curve)


def _tick_layers(n, n_frozen):
    """Unit-cost frozen blocks, zero-cost hot blocks + hops: the engine's
    time unit becomes exactly one frozen-trunk tick."""
    frozen = LayerProfile(fwd_s=1.0, bwd_s=0.0, act_mb=1.0, weight_mb=1.0,
                          adapter_mb=0.1, boundary_mb=0.0)
    hot = LayerProfile(fwd_s=0.0, bwd_s=0.0, act_mb=1.0, weight_mb=1.0,
                       adapter_mb=0.1, boundary_mb=0.0)
    return [frozen] * n_frozen + [hot] * (n - n_frozen)


@pytest.mark.parametrize("S,M,F", [(4, 3, 3), (4, 4, 2), (3, 2, 2), (2, 4, 1)])
def test_packed_conveyor_ticks_match_formula(S, M, F):
    """The discrete-event engine reproduces the closed forms the executor's
    packed Phase A is built on: one S*M+F-1-tick conveyor per round vs the
    scan's S separate M+F-1-tick pipelines, saving (S-1)(F-1) ticks."""
    sim = SimConfig(n_layers=S, n_devices=S, n_microbatches=M)
    layers = _tick_layers(S, F)
    devices = [DeviceProfile(1.0, 4096)] * S
    depth = S - F                                  # hot blocks above boundary
    r_scan = simulate_round("ringada", sim, layers, devices,
                            unfreeze_depth=depth, n_owners=S)
    r_packed = simulate_round("ringada_packed", sim, layers, devices,
                              unfreeze_depth=depth, n_owners=S)
    t_scan = pipeline_tick_counts(S, M, boundary=F, lps=1)
    t_packed = pipeline_tick_counts(S, M, boundary=F, lps=1, packed=True)
    # formula == engine, both schemes (hot region costs 0 by construction)
    assert r_scan.time_per_round_s == t_scan["phase_a_round_ticks"] \
        == S * (M + F - 1)
    assert r_packed.time_per_round_s == t_packed["phase_a_round_ticks"] \
        == S * M + F - 1
    # and the advertised per-round saving
    saved = r_scan.time_per_round_s - r_packed.time_per_round_s
    assert saved == t_packed["phase_a_saved_ticks"] == (S - 1) * (F - 1)


def test_packed_single_owner_equals_ringada():
    """n_owners=1 has no cross-owner bubbles to pack away: both schemes
    reduce to the same schedule."""
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    layers = [LayerProfile(0.01, 0.02, 20.0, 30.0, 0.6, 2.0)] * 12
    devices = [DeviceProfile(1.0, 4096, 1000.0)] * 4
    r = simulate_round("ringada", sim, layers, devices, unfreeze_depth=3)
    p = simulate_round("ringada_packed", sim, layers, devices,
                       unfreeze_depth=3)
    assert p.time_per_round_s == r.time_per_round_s


def test_packed_trades_terminator_memory_for_time():
    """The conveyor queues later owners' boundary activations at the
    terminator: packed is strictly faster over a full multi-owner round but
    the terminator's peak memory grows by (n_owners-1)*M boundary tensors."""
    S, M, F = 4, 4, 3
    sim = SimConfig(n_layers=S, n_devices=S, n_microbatches=M)
    frozen = LayerProfile(1.0, 0.0, 1.0, 1.0, 0.1, boundary_mb=2.0)
    hot = LayerProfile(0.5, 1.0, 1.0, 1.0, 0.1, boundary_mb=2.0)
    layers = [frozen] * F + [hot] * (S - F)
    devices = [DeviceProfile(1.0, 4096)] * S
    r = simulate_round("ringada", sim, layers, devices,
                       unfreeze_depth=S - F, n_owners=S)
    p = simulate_round("ringada_packed", sim, layers, devices,
                       unfreeze_depth=S - F, n_owners=S)
    assert p.time_per_round_s < r.time_per_round_s
    term = F                                            # terminator device
    extra = (S - 1) * M * 2.0
    assert p.peak_memory_mb[term] == r.peak_memory_mb[term] + extra


def test_tick_counts_cached_and_packed_consistent():
    """phase_a_round_ticks: cached kills Phase A entirely, packed only the
    cross-owner bubbles; at F<=1 or F=0 packing saves nothing."""
    base = pipeline_tick_counts(4, 8, boundary=9, lps=3)
    packed = pipeline_tick_counts(4, 8, boundary=9, lps=3, packed=True)
    cached = pipeline_tick_counts(4, 8, boundary=9, lps=3, cached=True)
    assert base["phase_a_round_ticks"] == 4 * (8 + 3 - 1)
    assert packed["phase_a_round_ticks"] == 4 * 8 + 3 - 1
    assert packed["phase_a_saved_ticks"] == 3 * 2
    assert cached["phase_a_round_ticks"] == 0
    assert cached["fwd_ticks"] == packed["fwd_ticks"]   # both hoist Phase A
    for b, lps in ((0, 3), (3, 3)):                     # F == 0 / F == 1
        t = pipeline_tick_counts(4, 8, boundary=b, lps=lps, packed=True)
        assert t["phase_a_saved_ticks"] == 0


def test_heterogeneous_devices_respected():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=4)
    slow = [DeviceProfile(0.25, 4096), DeviceProfile(1.0, 4096),
            DeviceProfile(1.0, 4096), DeviceProfile(1.0, 4096)]
    fast = _devices()
    r_slow = simulate_round("pipe_adapter", sim, _layers(), slow)
    r_fast = simulate_round("pipe_adapter", sim, _layers(), fast)
    assert r_slow.time_per_round_s > r_fast.time_per_round_s
