"""The trace-based simulator must reproduce the paper's qualitative Table I:
RingAda < PipeAdapter < Single on both time and memory."""

from repro.core.partition import DeviceProfile
from repro.core.simulator import (LayerProfile, SimConfig, simulate_round,
                                  simulate_training)


def _layers(n=12):
    return [LayerProfile(fwd_s=0.01, bwd_s=0.02, act_mb=20.0, weight_mb=30.0,
                         adapter_mb=0.6, boundary_mb=2.0)] * n


def _devices(u=4):
    return [DeviceProfile(compute_speed=1.0, memory_mb=4096,
                          link_mbps=1000.0)] * u


def test_single_vs_pipeline_time():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8,
                    head_fwd_s=0.002, head_bwd_s=0.004, head_mb=50, embed_mb=50)
    r_single = simulate_round("single", sim, _layers(), _devices())
    r_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices())
    assert r_pipe.time_per_round_s < r_single.time_per_round_s


def test_ringada_faster_than_pipeadapter_when_frozen():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    r_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices())
    r_ring = simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=3)
    assert r_ring.time_per_round_s < r_pipe.time_per_round_s


def test_memory_ordering_matches_table1():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8,
                    head_mb=50, embed_mb=50)
    m_single = simulate_round("single", sim, _layers(), _devices()
                              ).max_memory_mb
    m_pipe = simulate_round("pipe_adapter", sim, _layers(), _devices()
                            ).max_memory_mb
    m_ring = simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=3).max_memory_mb
    assert m_ring < m_pipe < m_single


def test_deeper_unfreezing_costs_more():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    times = [simulate_round("ringada", sim, _layers(), _devices(),
                            unfreeze_depth=d).time_per_round_s
             for d in (1, 6, 12)]
    assert times[0] < times[1] <= times[2]


def test_training_schedule_integration():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=8)
    t_ring, m_ring, curve = simulate_training(
        "ringada", sim, _layers(), _devices(), rounds=50,
        unfreeze_interval=10)
    t_pipe, m_pipe, _ = simulate_training(
        "pipe_adapter", sim, _layers(), _devices(), rounds=50)
    assert t_ring < t_pipe
    assert m_ring < m_pipe
    assert len(curve) == 50 and curve == sorted(curve)


def test_heterogeneous_devices_respected():
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=4)
    slow = [DeviceProfile(0.25, 4096), DeviceProfile(1.0, 4096),
            DeviceProfile(1.0, 4096), DeviceProfile(1.0, 4096)]
    fast = _devices()
    r_slow = simulate_round("pipe_adapter", sim, _layers(), slow)
    r_fast = simulate_round("pipe_adapter", sim, _layers(), fast)
    assert r_slow.time_per_round_s > r_fast.time_per_round_s
