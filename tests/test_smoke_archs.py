"""Per-architecture smoke tests (deliverable f): reduced variant of each family
runs one forward AND one train step on CPU — output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, TrainConfig, get_config
from repro.core import training
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.optim import adamw


def _setup(name):
    cfg = get_config(name).reduced()
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    B, S = 2, 64
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                     cfg.vocab_size),
    }
    if cfg.frontend or cfg.enc_dec:
        batch["memory"] = 0.1 * jax.random.normal(
            jax.random.key(3), (B, 16, cfg.d_model), jnp.bfloat16)
    return cfg, params, batch


@pytest.mark.parametrize("name", ASSIGNED + ["mbert-squad"])
def test_forward_shapes_no_nan(name):
    cfg, params, batch = _setup(name)
    logits, aux = tfm.forward(params, batch["tokens"], cfg,
                              memory=batch.get("memory"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.out_dim)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    for v in aux.values():
        assert not bool(jnp.isnan(v).any())


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_no_nan(name):
    cfg, params, batch = _setup(name)
    tc = TrainConfig(learning_rate=1e-3)
    opt = adamw.init(training.full_trainable(params))
    boundary = cfg.repeats - 1            # top block unfrozen (paper's start)
    step = jax.jit(training.make_train_step(cfg, tc, boundary))
    p2, o2, m = step(params, opt, batch)
    assert not bool(jnp.isnan(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # only hot adapters + head moved
    for e0, e1 in zip(params["blocks"], p2["blocks"]):
        for k in e0["adapter"]:
            a0, a1 = e0["adapter"][k], e1["adapter"][k]
            assert jnp.array_equal(a0[:boundary], a1[:boundary]), "frozen moved"
        for k in ("ln1",):
            if k in e0:
                assert jax.tree.all(jax.tree.map(jnp.array_equal, e0[k], e1[k]))
    assert not jnp.array_equal(params["head"]["w"], p2["head"]["w"])
    assert jnp.array_equal(params["embed"]["tok"], p2["embed"]["tok"])


@pytest.mark.slow
@pytest.mark.parametrize("name", ["stablelm-3b", "olmoe-1b-7b", "rwkv6-7b",
                                  "hymba-1.5b"])
def test_two_steps_loss_finite_and_decreasing_grads(name):
    cfg, params, batch = _setup(name)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=1)
    opt = adamw.init(training.full_trainable(params))
    step = jax.jit(training.make_train_step(cfg, tc, 0))
    p, o = params, opt
    losses = []
    for _ in range(3):
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]          # overfits one batch quickly
