"""Optimizer, data pipeline, checkpointing, sharding rules, roofline parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.data.pipeline import Batcher, RingBatcher, make_client_datasets
from repro.checkpoint import checkpoint as ckpt
from repro.models import params as prm
from repro.optim import adamw
from repro import roofline as rl
from repro import sharding as sh


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _tiny():
    cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4)
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0))
    return cfg, params


def test_adamw_row_masking():
    cfg, params = _tiny()
    tr_full = training.full_trainable(params)
    opt = adamw.init(tr_full)
    b = 2
    grads = {"adapters": tuple(
        jax.tree.map(lambda x: jnp.ones_like(x[b:], jnp.float32), e["adapter"])
        for e in params["blocks"]),
        "head": jax.tree.map(lambda x: jnp.ones_like(x, jnp.float32),
                             params["head"])}
    tc = TrainConfig(learning_rate=0.1, warmup_steps=1)
    new_tr, new_opt = adamw.update(grads, opt, tr_full, tc, b)
    wd0 = tr_full["adapters"][0]["w_down"]
    wd1 = new_tr["adapters"][0]["w_down"]
    assert jnp.array_equal(wd0[:b], wd1[:b])             # frozen untouched
    assert not jnp.array_equal(wd0[b:], wd1[b:])         # hot updated
    assert int(new_opt["count"]) == 1
    # frozen moments remain exactly zero
    assert float(jnp.abs(new_opt["m"]["adapters"][0]["w_down"][:b]).max()) == 0


@pytest.mark.slow
def test_adamw_state_stable_across_boundaries():
    cfg, params = _tiny()
    opt = adamw.init(training.full_trainable(params))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (2, 32), 0,
                                          cfg.vocab_size)}
    tc = TrainConfig()
    p, o = params, opt
    for b in (3, 2, 1):                    # schedule moves, state tree constant
        step = jax.jit(training.make_train_step(cfg, tc, b))
        p, o, _ = step(p, o, batch)
    assert jax.tree.structure(o) == jax.tree.structure(opt)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_client_datasets_distinct_and_deterministic():
    a = make_client_datasets(3, vocab=97, n_per_client=8, seq=16, seed=1)
    b = make_client_datasets(3, vocab=97, n_per_client=8, seq=16, seed=1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
    assert not np.array_equal(a[0].tokens, a[1].tokens)
    assert a[0].tokens.max() < 97 and a[0].tokens.min() >= 0
    # lm labels are shifted tokens
    np.testing.assert_array_equal(a[0].labels[:, :-1], a[0].tokens[:, 1:])


def test_ring_batcher_shapes():
    ds = make_client_datasets(4, vocab=50, n_per_client=16, seq=8, seed=0)
    rb = RingBatcher(ds, n_micro=3, micro_batch=2, seed=0)
    t, l = rb.next()
    assert t.shape == (4, 3, 2, 8) and l.shape == (4, 3, 2, 8)


def test_qa_datasets():
    ds = make_client_datasets(2, vocab=100, n_per_client=8, seq=32, seed=0,
                              kind="qa")
    b = Batcher(ds[0], 4, seed=0).next()
    assert b["starts"].shape == (4,)
    assert (np.asarray(b["ends"]) >= np.asarray(b["starts"])).all()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    cfg, params = _tiny()
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, step=7)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored, meta = ckpt.restore(path, zeros)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_adapters_only(tmp_path):
    cfg, params = _tiny()
    path = os.path.join(tmp_path, "ad")
    ckpt.save(path, params, adapters_only=True)
    data = np.load(path + ".npz")
    assert all(("adapter" in k.split("::")) or k.startswith("head")
               for k in data.files)
    assert any("adapter" in k for k in data.files)
    # restore keeps non-adapter leaves from the template
    tpl = jax.tree.map(jnp.zeros_like, params)
    restored, _ = ckpt.restore(path, tpl)
    assert float(jnp.abs(restored["embed"]["tok"]).max()) == 0
    np.testing.assert_array_equal(
        np.asarray(restored["head"]["w"], np.float32),
        np.asarray(params["head"]["w"], np.float32))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_spec_for_divisibility():
    rules = {"_axis_sizes": {"data": 16, "model": 16, "pod": 2},
             "kv_heads": "model", "embed": ("pod", "data"), "vocab": "model"}
    from jax.sharding import PartitionSpec as P
    # kv=8 can't shard over 16 -> replicated
    assert sh.spec_for(("embed", "kv_heads", None), rules,
                       (5120, 8, 128)) == P(("pod", "data"), None, None)
    # 24 divisible by pod(2) but not pod*data(32) -> prefix kept
    assert sh.spec_for(("embed",), rules, (24,)) == P("pod")
    assert sh.spec_for(("vocab",), rules, (256206,)) == P(None)
    assert sh.spec_for(("vocab",), rules, (49152,)) == P("model")


def test_spec_never_reuses_axis():
    rules = {"_axis_sizes": {"data": 4}, "batch": ("data",), "kv_seq": "data"}
    from jax.sharding import PartitionSpec as P
    s = sh.spec_for(("batch", "kv_seq"), rules, (8, 64))
    assert s == P("data", None)
    s = sh.spec_for(("batch", "kv_seq"), rules, (1, 64))   # batch=1: drop
    assert s == P(None, "data")


# ---------------------------------------------------------------------------
# Roofline HLO parsing
# ---------------------------------------------------------------------------


HLO = """
HloModule test

%body.1 (p: (f32[128,256])) -> (f32[128,256]) {
  %ag = f32[256,256]{1,0} all-gather(f32[16,256]{1,0} %x), replica_groups={}
  ROOT %t = (f32[128,256]) tuple(%ag2)
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %w = (f32[128,256]) while((f32[128,256]) %init), condition=%c, body=%body.1, backend_config={"known_trip_count":{"n":"8"}}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %y), to_apply=%sum
  ROOT %r = f32[128,256]{1,0} copy(%ar)
}
"""


def test_collective_bytes_trip_counts():
    out = rl.collective_bytes(HLO)
    # all-gather operand: 16*256*4 = 16384 bytes, x8 trips = 131072
    assert out["all-gather"] == 16 * 256 * 4 * 8
    # all-reduce operand: 128*256*4 once
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_model_flops_conventions():
    from repro.configs import INPUT_SHAPES
    cfg = get_config("olmoe-1b-7b")
    mf_t = rl.model_flops(cfg, INPUT_SHAPES["train_4k"])
    mf_d = rl.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert mf_t["n_active"] < mf_t["n_params"]
    assert mf_t["model_flops"] == 6.0 * mf_t["n_active"] * 256 * 4096
    assert mf_d["model_flops"] == 2.0 * mf_d["n_active"] * 128
