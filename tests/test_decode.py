"""Serving correctness: prefill + decode_step must agree with the full forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.models import kvcache

NON_MOE = [n for n in ASSIGNED if get_config(n).moe is None]
MOE = [n for n in ASSIGNED if get_config(n).moe is not None]


def _setup(name, B=2, S=32):
    cfg = get_config(name).reduced()
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend or cfg.enc_dec:
        kw["memory"] = 0.1 * jax.random.normal(jax.random.key(2),
                                               (B, 16, cfg.d_model),
                                               jnp.bfloat16)
    return cfg, params, tokens, kw


@pytest.mark.parametrize("name", NON_MOE)
def test_prefill_decode_matches_forward(name):
    cfg, params, tokens, kw = _setup(name)
    S = tokens.shape[1]
    full, _ = tfm.forward(params, tokens, cfg, **kw)
    pl, cache = tfm.prefill(params, tokens[:, :S - 1], cfg, seq_len=256, **kw)
    dl, cache2 = tfm.decode_step(params, tokens[:, S - 1:S], cache, cfg)
    f32 = lambda x: x.astype(jnp.float32)
    assert jnp.allclose(f32(pl), f32(full[:, S - 2]), atol=2e-2)
    assert jnp.allclose(f32(dl), f32(full[:, S - 1]), atol=2e-2)
    assert int(cache2["next"][0]) == int(cache["next"][0]) + 1


@pytest.mark.parametrize("name", MOE)
def test_prefill_decode_matches_forward_moe(name):
    # MoE decode can legitimately differ where full-seq routing dropped tokens
    # (capacity) — tolerance covers the gate-weighted expert output delta.
    # The prefill comparison sees the same effect (capacity is computed over
    # S-1 vs S tokens), so it gets a wider budget than the dense variant too.
    cfg, params, tokens, kw = _setup(name)
    S = tokens.shape[1]
    full, _ = tfm.forward(params, tokens, cfg, **kw)
    pl, cache = tfm.prefill(params, tokens[:, :S - 1], cfg, seq_len=256, **kw)
    dl, _ = tfm.decode_step(params, tokens[:, S - 1:S], cache, cfg)
    f32 = lambda x: x.astype(jnp.float32)
    assert jnp.allclose(f32(pl), f32(full[:, S - 2]), atol=5e-2)
    assert jnp.allclose(f32(dl), f32(full[:, S - 1]), atol=0.5)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["qwen2.5-3b", "hymba-1.5b", "rwkv6-7b"])
def test_multistep_greedy_decode_matches_forward(name):
    """Greedy continuation via cache == greedy continuation via re-forward."""
    cfg, params, tokens, kw = _setup(name, B=1, S=16)
    n_new = 6
    _, cache = tfm.prefill(params, tokens, cfg, seq_len=256, **kw)
    cur = tokens
    nxt = None
    cached_out = []
    logits, _ = tfm.forward(params, cur, cfg, **kw)
    step_tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(n_new):
        cached_out.append(int(step_tok[0, 0]))
        logits1, cache = tfm.decode_step(params, step_tok, cache, cfg)
        step_tok = jnp.argmax(logits1, -1)[:, None].astype(jnp.int32)

    ref_out = []
    cur = tokens
    for _ in range(n_new):
        logits, _ = tfm.forward(params, cur, cfg, **kw)
        t = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        ref_out.append(int(t[0, 0]))
        cur = jnp.concatenate([cur, t], axis=1)
    assert cached_out == ref_out


def test_sliding_window_cache_bounded():
    cfg = get_config("starcoder2-7b").reduced()     # window 128 after reduce
    assert cfg.sliding_window == 128
    c = kvcache.init_cache(cfg, 1, 4096)
    assert c["layers"][0]["k"].shape[3] == 128       # Ck = window, not 4096
    assert kvcache.cache_len(cfg, 4096) == 128


def test_rwkv_cache_constant_size():
    cfg = get_config("rwkv6-7b").reduced()
    c1 = kvcache.init_cache(cfg, 1, 128)
    c2 = kvcache.init_cache(cfg, 1, 4096)
    # attention-free: state size independent of horizon (pos array aside)
    s1 = c1["layers"][0]["state"].size
    s2 = c2["layers"][0]["state"].size
    assert s1 == s2


def test_sliding_window_decode_correct_beyond_window():
    """Decode far past the window: ring buffer must match a windowed forward."""
    import dataclasses
    cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                              sliding_window=8)
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    S = 24
    tokens = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    full, _ = tfm.forward(params, tokens, cfg)        # masked SWA reference
    _, cache = tfm.prefill(params, tokens[:, :S - 1], cfg, seq_len=64)
    dl, _ = tfm.decode_step(params, tokens[:, S - 1:S], cache, cfg)
    assert jnp.allclose(dl.astype(jnp.float32),
                        full[:, S - 1].astype(jnp.float32), atol=2e-2)


def test_int8_kv_cache_decode():
    """Beyond-paper: int8 KV cache halves decode memory at bounded logit error."""
    import dataclasses
    for name in ["stablelm-3b", "hymba-1.5b"]:
        cfg = dataclasses.replace(get_config(name).reduced(), kv_quant=True)
        params = prm.materialize(prm.param_defs(cfg), jax.random.key(0),
                                 cfg.dtype)
        B, S = 2, 32
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
        full, _ = tfm.forward(params, tokens, cfg)
        _, cache = tfm.prefill(params, tokens[:, :S - 1], cfg, seq_len=256)
        assert cache["layers"][0]["k"].dtype == jnp.int8
        dl, _ = tfm.decode_step(params, tokens[:, S - 1:S], cache, cfg)
        err = jnp.abs(dl.astype(jnp.float32)
                      - full[:, S - 1].astype(jnp.float32)).max()
        assert float(err) < 0.5
        # byte accounting: int8 k/v + bf16 scales < half of bf16 k/v
        q = kvcache.cache_bytes(kvcache.init_cache(cfg, 1, 1024))
        f = kvcache.cache_bytes(kvcache.init_cache(
            dataclasses.replace(cfg, kv_quant=False), 1, 1024))
        assert q < 0.6 * f
