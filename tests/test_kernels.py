"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import adapter_fused as afk
from repro.kernels import flash_attention as fak
from repro.kernels import rwkv_scan as rsk


@pytest.mark.parametrize("T,D,m", [(128, 128, 32), (256, 512, 64),
                                   (300, 256, 48), (64, 1024, 16)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("act", ["gelu", "relu", "silu"])
def test_adapter_fused_sweep(T, D, m, dtype, act):
    if act != "gelu" and (T, D, m) != (256, 512, 64):
        pytest.skip("activation sweep on one shape only")
    key = jax.random.key(0)
    h = jax.random.normal(key, (T, D), dtype)
    wd = 0.05 * jax.random.normal(jax.random.key(1), (D, m), jnp.float32)
    wu = 0.05 * jax.random.normal(jax.random.key(2), (m, D), jnp.float32)
    got = afk.adapter_fused(h, wd, wu, activation=act, interpret=True)
    want = ref.adapter_fused(h, wd, wu, activation=act)
    atol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("N,S,hd,chunk", [(2, 32, 16, 8), (4, 64, 32, 32),
                                          (1, 96, 64, 32), (3, 40, 8, 16)])
def test_rwkv_scan_sweep(N, S, hd, chunk):
    keys = jax.random.split(jax.random.key(0), 6)
    r, k, v = (jax.random.normal(keys[i], (N, S, hd), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(0.5 * jax.random.normal(keys[3], (N, S, hd)) - 1.0)
    u = 0.5 * jax.random.normal(keys[4], (N, 1, hd))
    s0 = 0.1 * jax.random.normal(keys[5], (N, hd, hd))
    got, gT = rsk.rwkv_scan(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    want, wT = ref.rwkv_scan(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gT), np.asarray(wT),
                               atol=1e-3, rtol=1e-3)


def test_rwkv_scan_state_chaining():
    """Two half-sequences with state carry == one full sequence."""
    N, S, hd = 2, 64, 16
    keys = jax.random.split(jax.random.key(1), 6)
    r, k, v = (jax.random.normal(keys[i], (N, S, hd), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(0.5 * jax.random.normal(keys[3], (N, S, hd)) - 1.0)
    u = 0.5 * jax.random.normal(keys[4], (N, 1, hd))
    s0 = jnp.zeros((N, hd, hd))
    full, sT = rsk.rwkv_scan(r, k, v, lw, u, s0, chunk=16, interpret=True)
    h1, s1 = rsk.rwkv_scan(r[:, :32], k[:, :32], v[:, :32], lw[:, :32], u, s0,
                           chunk=16, interpret=True)
    h2, s2 = rsk.rwkv_scan(r[:, 32:], k[:, 32:], v[:, 32:], lw[:, 32:], u, s1,
                           chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sT),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("Sq,Sk,hd,group,window", [
    (128, 128, 64, 1, None),
    (128, 128, 64, 4, None),
    (256, 256, 32, 2, 64),
    (128, 256, 64, 1, None),          # decode-ish: fewer queries than keys
    (128, 128, 128, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_sweep(Sq, Sk, hd, group, window, dtype):
    Nk = 2
    Nq = Nk * group
    q = jax.random.normal(jax.random.key(0), (Nq, Sq, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (Nk, Sk, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (Nk, Sk, hd), dtype)
    got = fak.flash_attention(q, k, v, group=group, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = jnp.stack([
        ref.flash_attention(q[i:i + 1], k[i // group:i // group + 1],
                            v[i // group:i // group + 1], window=window)[0]
        for i in range(Nq)])
    atol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_ops_wrappers_jit():
    h = jax.random.normal(jax.random.key(0), (4, 8, 64), jnp.bfloat16)
    wd = 0.1 * jax.random.normal(jax.random.key(1), (64, 16), jnp.float32)
    wu = 0.1 * jax.random.normal(jax.random.key(2), (16, 64), jnp.float32)
    out = ops.adapter_fused(h, wd, wu)         # leading dims flattened inside
    assert out.shape == h.shape
    want = ref.adapter_fused(h.reshape(-1, 64), wd, wu).reshape(h.shape)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


def test_model_uses_pallas_adapter_consistently():
    """impl='pallas' must match impl='jnp' end-to-end on a block stack."""
    from repro.configs import get_config
    from repro.models import params as prm
    from repro.models import transformer as tfm
    cfg = get_config("rwkv6-7b").reduced()
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    tokens = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    a, _ = tfm.forward(params, tokens, cfg, impl="jnp")
    b, _ = tfm.forward(params, tokens, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-2)


@pytest.mark.parametrize("B,S,D,N,chunk", [(2, 32, 8, 4, 8), (1, 64, 16, 8, 16),
                                           (3, 48, 4, 16, 16)])
def test_mamba_scan_sweep(B, S, D, N, chunk):
    from repro.kernels import mamba_scan as msk
    keys = jax.random.split(jax.random.key(0), 3)
    log_a = -jnp.exp(0.5 * jax.random.normal(keys[0], (B, S, D, N)) - 1.0)
    b = jax.random.normal(keys[1], (B, S, D, N), jnp.float32) * 0.5
    c = jax.random.normal(keys[2], (B, S, N), jnp.float32)
    got_y, got_s = msk.mamba_scan(log_a, b, c, chunk=chunk, interpret=True)
    want_y, want_s = ref.mamba_scan(log_a, b, c)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-4, rtol=1e-4)
