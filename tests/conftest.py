import os
import sys

# Tests must see the default 1-device CPU backend (the dry-run sets its own
# 512-device flag in a separate process). Keep compile times sane.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
