"""Property-based tests (hypothesis) on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt); "
           "skipping must not break collection of the rest of the suite")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.partition import DeviceProfile, assign_layers
from repro.core.unfreeze import UnfreezeSchedule, depth_to_boundary
from repro.models import kvcache
from repro.models.blocks import moe_ffn
from repro.models.losses import cross_entropy

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# KV ring-buffer slots
# ---------------------------------------------------------------------------


@given(window=st.integers(4, 64), sink=st.sampled_from([0, 128]),
       horizon=st.integers(65, 2048))
@settings(**SETTINGS)
def test_write_slot_invariants(window, sink, horizon):
    cfg = get_config("hymba-1.5b" if sink else "qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=window)
    ck = kvcache.cache_len(cfg, horizon)
    ns = kvcache.n_sink(cfg)
    pos = jnp.arange(horizon)
    slots = np.asarray(kvcache.write_slot(cfg, pos, horizon))
    assert slots.min() >= 0 and slots.max() < max(ck, horizon if ck == horizon else ck)
    if ck < horizon:
        # the last `window` positions occupy distinct slots (no premature evict)
        w = ck - ns
        recent = slots[-w:]
        assert len(set(recent.tolist())) == w
        # sink positions are immovable
        assert (slots[:ns] == np.arange(ns)).all()


@given(sp=st.integers(1, 300), window=st.integers(4, 48))
@settings(**SETTINGS)
def test_prefill_fill_positions_match_write_order(sp, window):
    """The gather-fill formula must equal replaying sequential writes."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                              sliding_window=window)
    horizon = 4096
    ck = kvcache.cache_len(cfg, horizon)
    # replay: write positions 0..sp-1 one by one
    ref = -np.ones(ck, np.int64)
    slots = np.asarray(kvcache.write_slot(cfg, jnp.arange(sp), horizon))
    for p, s in enumerate(slots):
        ref[s] = p
    # closed form from transformer.prefill
    s_idx = np.arange(ck)
    w = ck
    cand = s_idx + w * (np.maximum(sp - 1 - s_idx, 0) // w)
    fill = np.where(cand < sp, cand, -1)
    np.testing.assert_array_equal(fill, ref)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


@given(T=st.integers(8, 96), E=st.sampled_from([4, 8]),
       k=st.integers(1, 3), seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_moe_dispatch_invariants(T, E, k, seed):
    from repro.configs.base import MoEConfig, ModelConfig
    cfg = ModelConfig(name=f"t{seed}", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      pattern=(("moe", 1),),
                      moe=MoEConfig(n_experts=E, top_k=k, d_expert=16,
                                    capacity_factor=8.0))  # no drops
    key = jax.random.key(seed)
    x = jax.random.normal(key, (1, T, 16), jnp.float32)
    from repro.models import params as prm
    p = prm.materialize(prm.moe_defs(cfg), key, "float32")
    out, aux = moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["moe_aux"]) >= 0
    # with capacity_factor high enough nothing drops: output must equal the
    # dense (all-experts) reference combined with the same gates
    logits = (x.reshape(T, 16) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    xe = x.reshape(T, 16)
    dense = jnp.zeros_like(xe)
    act = jax.nn.silu
    for e in range(E):
        ye = (act(xe @ p["we_gate"][e]) * (xe @ p["we_up"][e])) @ p["we_down"][e]
        wsel = ((eidx == e) * gates).sum(-1)
        dense += wsel[:, None] * ye
    shared = (act(xe @ p["ws_gate"]) * (xe @ p["ws_up"])) @ p["ws_down"]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(dense + shared),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Partitioner
# ---------------------------------------------------------------------------


@given(n=st.integers(4, 24), u=st.integers(2, 4), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_assign_layers_contiguous_complete(n, u, seed):
    if n < u:
        return
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.5, 2.0, n).tolist()
    mems = rng.uniform(1.0, 3.0, n).tolist()
    devs = [DeviceProfile(compute_speed=float(rng.uniform(0.5, 2.0)),
                          memory_mb=1e9) for _ in range(u)]
    spans = assign_layers(costs, mems, devs)
    assert len(spans) == u
    assert spans[0][0] == 0 and spans[-1][1] == n
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c and a < b
    # bottleneck no worse than the trivial single-heavy-device assignment
    bt = max(sum(costs[a:b]) / devs[i].compute_speed
             for i, (a, b) in enumerate(spans))
    worst = sum(costs) / max(d.compute_speed for d in devs)
    assert bt <= worst + 1e-9


@given(n=st.integers(4, 9), u=st.integers(2, 4), seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_assign_layers_memory_feasible_and_bottleneck_optimal(n, u, seed):
    """Under tight random memory budgets: every span fits its device's
    budget, and the realized bottleneck equals the brute-force optimum over
    ALL memory-feasible contiguous partitions (small n — exhaustive)."""
    import itertools
    if n < u:
        return
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.2, 2.0, n).tolist()
    mems = rng.uniform(0.5, 2.0, n).tolist()
    devs = [DeviceProfile(compute_speed=float(rng.uniform(0.3, 2.0)),
                          memory_mb=float(rng.uniform(2.0, 7.0)))
            for _ in range(u)]
    best = None
    for cuts in itertools.combinations(range(1, n), u - 1):
        edges = (0,) + cuts + (n,)
        t, ok = 0.0, True
        for i, dev in enumerate(devs):
            a, b = edges[i], edges[i + 1]
            if sum(mems[a:b]) > dev.memory_mb:
                ok = False
                break
            t = max(t, sum(costs[a:b]) / dev.compute_speed)
        if ok and (best is None or t < best):
            best = t
    if best is None:
        with pytest.raises(ValueError):
            assign_layers(costs, mems, devs)
        return
    spans = assign_layers(costs, mems, devs)
    for (a, b), dev in zip(spans, devs):
        assert sum(mems[a:b]) <= dev.memory_mb + 1e-12
    got = max(sum(costs[a:b]) / dev.compute_speed
              for (a, b), dev in zip(spans, devs))
    assert got <= best * (1 + 1e-9) + 1e-12


@given(n=st.integers(2, 40), u=st.integers(1, 8))
@settings(**SETTINGS)
def test_uniform_assignment_balanced_any_shape(n, u):
    """The divisibility crash is gone: any (n, u <= n) yields a contiguous
    cover whose span sizes differ by at most one."""
    from repro.core.partition import span_sizes, uniform_assignment
    if u > n:
        return
    spans = uniform_assignment(n, u)
    assert spans[0][0] == 0 and spans[-1][1] == n
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c
    sizes = span_sizes(spans)
    assert max(sizes) - min(sizes) <= 1
    assert sum(sizes) == n


# ---------------------------------------------------------------------------
# Unfreeze schedule
# ---------------------------------------------------------------------------


@given(d0=st.integers(1, 4), k=st.integers(1, 100), step=st.integers(0, 5000),
       L=st.integers(4, 64))
@settings(**SETTINGS)
def test_depth_monotone_and_capped(d0, k, step, L):
    s = UnfreezeSchedule(d0, k)
    d1, d2 = s.depth_at(step, L), s.depth_at(step + k, L)
    assert 1 <= d1 <= L
    assert d2 >= d1                    # monotone unfreezing (never re-freeze)


@given(depth=st.integers(1, 48))
@settings(**SETTINGS)
def test_boundary_depth_roundtrip(depth):
    for name in ("stablelm-3b", "llama-3.2-vision-11b"):
        cfg = get_config(name)
        b = depth_to_boundary(cfg, min(depth, cfg.n_layers))
        assert 0 <= b <= cfg.repeats
        # unfrozen layers >= requested depth (rounding is up, never down)
        assert (cfg.repeats - b) * cfg.layers_per_repeat >= min(depth, cfg.n_layers)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_cross_entropy_matches_manual(seed):
    key = jax.random.key(seed)
    logits = jax.random.normal(key, (2, 5, 11), jnp.float32)
    labels = jax.random.randint(jax.random.key(seed + 1), (2, 5), 0, 11)
    loss, m = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.take_along_axis(p, labels[..., None], -1).mean()
    assert abs(float(loss) - float(want)) < 1e-5
    # shift-invariance of softmax
    loss2, _ = cross_entropy(logits + 100.0, labels)
    assert abs(float(loss) - float(loss2)) < 1e-3
