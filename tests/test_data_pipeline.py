"""Data-pipeline determinism: the activation cache's key contract.

``(slot, boundary)`` identifies a cache entry, so the slot -> example mapping
must be a pure function of the seed: identical across epochs, across
re-instantiation, and undisturbed by interleaved random draws.
"""
import numpy as np
import pytest

from repro.data.pipeline import RingBatcher, make_client_datasets


def _mk(seed=0, slots=3, n_micro=2, mb=2):
    ds = make_client_datasets(4, vocab=64, n_per_client=32, seq=16, seed=1)
    return RingBatcher(ds, n_micro, mb, seed=seed, slots_per_epoch=slots)


def test_same_slot_same_examples_across_epochs():
    rb = _mk()
    epoch0 = [rb.next_slot() for _ in range(3)]
    epoch1 = [rb.next_slot() for _ in range(3)]
    assert rb.epoch == 2
    for (s0, t0, l0), (s1, t1, l1) in zip(epoch0, epoch1):
        assert s0 == s1
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_same_seed_same_mapping_across_instances():
    a, b = _mk(seed=7), _mk(seed=7)
    for _ in range(4):
        sa, ta, la = a.next_slot()
        sb, tb, lb = b.next_slot()
        assert sa == sb
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_different_seed_different_mapping():
    a, b = _mk(seed=0), _mk(seed=1)
    _, ta, _ = a.next_slot()
    _, tb, _ = b.next_slot()
    assert not np.array_equal(np.asarray(ta), np.asarray(tb))


def test_slots_distinct_within_epoch():
    rb = _mk()
    _, t0, _ = rb.next_slot()
    _, t1, _ = rb.next_slot()
    assert not np.array_equal(np.asarray(t0), np.asarray(t1))


def test_random_draws_do_not_perturb_slot_mapping():
    a, b = _mk(seed=3), _mk(seed=3)
    for _ in range(5):
        a.next()                         # streaming draws interleaved
    _, ta, _ = a.next_slot()
    _, tb, _ = b.next_slot()
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_slot_shapes_and_cycling():
    rb = _mk(slots=2, n_micro=3, mb=2)
    slots = []
    for _ in range(5):
        s, t, l = rb.next_slot()
        slots.append(s)
        assert t.shape == (4, 3, 2, 16) and l.shape == (4, 3, 2, 16)
    assert slots == [0, 1, 0, 1, 0]


def test_mid_epoch_cursor_start_materializes_correct_slots():
    """A restored session's batcher starts mid-epoch (cursor _t > 0), so the
    first slot visited may not be 0 — lazy slot materialization must key by
    slot, not by visit order (regression: IndexError + wrong-slot batches)."""
    fresh = _mk(slots=3)
    resumed = _mk(slots=3)
    resumed._t = 2                       # what RingDataSource.load_state does
    want = [fresh.next_slot() for _ in range(5)][2:]
    got = [resumed.next_slot() for _ in range(3)]
    for (s0, t0, l0), (s1, t1, l1) in zip(want, got):
        assert s0 == s1
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_next_slot_requires_slots_per_epoch():
    ds = make_client_datasets(2, vocab=64, n_per_client=16, seq=8, seed=0)
    rb = RingBatcher(ds, 2, 2, seed=0)
    with pytest.raises(ValueError, match="slots_per_epoch"):
        rb.next_slot()
    with pytest.raises(ValueError, match="slots_per_epoch"):
        RingBatcher(ds, 2, 2, seed=0, slots_per_epoch=0)
