"""Fused RingExecutor vs reference RingTrainer: run in a 4-device subprocess.

Pins the three contracts of the fused end-to-end step (core/executor.py):

  (a) equivalence — losses and exported params match the unfused reference
      over multiple rounds ACROSS a boundary bump (same adamw leaf math,
      different grad plumbing: traced-owner dynamic permutes + in-jit optimizer
      vs static ppermute tables + host optimizer),
  (b) stage-mask correctness — frozen stages' adapters and their Adam moments
      are bit-identical before and after training,
  (c) compile counts — exactly ONE trace/executable per boundary for the fused
      path vs S executables per boundary for the reference.
"""
import json
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.models import params as P
from repro.core.ring import RingTrainer
from repro.core.executor import RingExecutor

cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
S, M, mb, seq = 4, 3, 1, 32

def fresh_params():
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

mesh = compat.make_mesh((4,), ("stage",))
tokens = jax.random.randint(jax.random.key(1), (S, M, mb, seq), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (S, M, mb, seq), 0, cfg.vocab_size)
f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_fused_matches_reference_across_boundary_bump():
    """(a) + (c): 3 rounds crossing boundaries 3 -> 2 -> 1 (interval = S so the
    reference's per-iteration boundary equals the fused per-round boundary)."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
out = {"ref_loss": [], "fused_loss": [], "ref_b": [], "fused_b": []}
with compat.set_mesh(mesh):
    ref = RingTrainer(cfg, tc, mesh, fresh_params(), S, M)
    ex = RingExecutor(cfg, tc, mesh, fresh_params(), S, M)
    for r in range(3):
        mr = ref.round(tokens, labels)
        me = RingExecutor.materialize_metrics(ex.round(tokens, labels))
        out["ref_loss"].append(mr["loss"])
        out["fused_loss"].append(me["loss"])
        out["ref_b"].append(mr["boundary"])
        out["fused_b"].append(me["boundary"])
    out["param_err"] = maxerr(ref.export_params(), ex.export_params())
    out["fused_traces"] = ex.trace_counts
    out["fused_executables"] = ex.n_executables
    out["ref_executables"] = ref.n_executables
print(json.dumps(out))
"""
    res = _run_sub(code)
    # same schedule on both drivers
    assert res["fused_b"] == [3, 2, 1]
    assert res["ref_b"] == res["fused_b"]
    # (a) losses track within tolerance (bf16 params, different reduce orders)
    for rl, fl in zip(res["ref_loss"], res["fused_loss"]):
        assert abs(rl - fl) < 2e-2, (res["ref_loss"], res["fused_loss"])
    assert res["param_err"] < 5e-2
    # (c) exactly one compilation per boundary, vs S per boundary before
    assert res["fused_executables"] == 3
    assert all(n == 1 for n in res["fused_traces"].values()), res["fused_traces"]
    assert res["ref_executables"] == 3 * 4


def test_frozen_stages_and_moments_untouched():
    """(b): with boundary fixed at 3 (stages 0-2 frozen), frozen stages'
    adapter rows and Adam moments must be BIT-identical after 2 rounds, while
    the hot stage's adapters moved and its moments are nonzero."""
    code = PRELUDE + """
import numpy as np
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
with compat.set_mesh(mesh):
    ex = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, donate=False)
    ad0 = jax.tree.map(jnp.copy, ex.stage_blocks["adapter"])
    F = ex.boundary_at(0)    # == 3 (initial depth 1, 1 repeat per stage)
    for _ in range(2):
        ex.round(tokens, labels)
    frozen_equal = all(
        bool((a[:F] == b[:F]).all()) for a, b in
        zip(jax.tree.leaves(ad0), jax.tree.leaves(ex.stage_blocks["adapter"])))
    hot_moved = any(
        bool((a[F:] != b[F:]).any()) for a, b in
        zip(jax.tree.leaves(ad0), jax.tree.leaves(ex.stage_blocks["adapter"])))
    m_ad = ex.opt_state["m"]["adapter"]
    frozen_m_zero = all(bool((m[:F] == 0).all()) for m in jax.tree.leaves(m_ad))
    hot_m_nonzero = any(bool((m[F:] != 0).any()) for m in jax.tree.leaves(m_ad))
    print(json.dumps({"F": int(F), "frozen_equal": frozen_equal,
                      "hot_moved": hot_moved, "frozen_m_zero": frozen_m_zero,
                      "hot_m_nonzero": hot_m_nonzero,
                      "traces": ex.trace_counts}))
"""
    res = _run_sub(code)
    assert res["F"] == 3
    assert res["frozen_equal"], "frozen stages' adapters moved"
    assert res["hot_moved"], "hot stage never trained"
    assert res["frozen_m_zero"], "frozen stages' Adam moments were touched"
    assert res["hot_m_nonzero"]
    # same boundary both rounds: still exactly one compilation
    assert res["traces"] == {"3": 1}
