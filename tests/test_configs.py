"""The assigned architecture table is a contract — verify every number."""
import pytest

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, list_configs
from repro.models import params as prm


EXPECTED = {
    # name: (L, d_model, H, kv, d_ff, vocab)
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
}


@pytest.mark.parametrize("name", ASSIGNED)
def test_exact_assignment(name):
    cfg = get_config(name)
    L, d, H, kv, ff, V = EXPECTED[name]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V


def test_registry_complete():
    known = list_configs()
    for name in ASSIGNED:
        assert name in known
    assert "mbert-squad" in known          # the paper's own eval model


def test_moe_details():
    m = get_config("olmoe-1b-7b").moe
    assert (m.n_experts, m.top_k, m.d_expert) == (64, 8, 1024)
    m = get_config("moonshot-v1-16b-a3b").moe
    assert (m.n_experts, m.top_k, m.d_expert) == (64, 6, 1408)
    m = get_config("llama4-maverick-400b-a17b").moe
    assert (m.n_experts, m.top_k) == (128, 1)


def test_pattern_layer_counts():
    for name in ASSIGNED:
        cfg = get_config(name)
        assert cfg.repeats * cfg.layers_per_repeat == cfg.n_layers


def test_vlm_cross_layers():
    cfg = get_config("llama-3.2-vision-11b")
    assert cfg.pattern == (("dense", 4), ("cross", 1))
    assert cfg.repeats == 8                # 8 cross-attn layers of 40


def test_subquadratic_flags():
    runs_500k = {n for n in ASSIGNED
                 if get_config(n).subquadratic}
    assert runs_500k == {"starcoder2-7b", "qwen2.5-3b", "hymba-1.5b",
                         "rwkv6-7b", "llama4-maverick-400b-a17b"}


def test_param_counts_plausible():
    # active < total for MoE, equal for dense
    for name in ASSIGNED:
        cfg = get_config(name)
        defs = prm.param_defs(cfg)
        total = prm.count_params(defs)
        active = prm.count_active_params(cfg)
        if cfg.moe:
            assert active < total
        else:
            assert active == total
    n = prm.count_params(prm.param_defs(get_config("llama4-maverick-400b-a17b")))
    assert 3.5e11 < n < 4.7e11             # the "400b" in the name
    n = prm.count_params(prm.param_defs(get_config("starcoder2-7b")))
    assert 6e9 < n < 9e9


def test_reduced_variants_small():
    for name in ASSIGNED:
        r = get_config(name).reduced()
        assert r.d_model <= 512 and r.n_layers <= 2 * r.layers_per_repeat
        if r.moe:
            assert r.moe.n_experts <= 4


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
