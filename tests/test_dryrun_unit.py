"""Dry-run machinery on a small virtual mesh (subprocess, 16 host devices).

Validates the same lower->compile->analyze pipeline the 512-chip dry-run uses,
at a size CI can afford, plus the input-spec builders and the analytic-FLOPs
cross-check on real configs.
"""
import json
import os
import subprocess
import sys

import pytest

from repro import roofline as rl
from repro.configs import ASSIGNED, INPUT_SHAPES, get_config

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_lower_compile_small_mesh():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, dataclasses
import jax
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.launch import inputs as inp
from repro import sharding as sh
from repro.models import params as prm

mesh = compat.make_mesh((4, 4), ("data", "model"))
out = {}
for arch in ["stablelm-3b", "olmoe-1b-7b", "rwkv6-7b"]:
    cfg = get_config(arch).reduced(d_model=256, n_heads=4, n_kv_heads=4)
    rules = sh.default_rules(mesh)
    defs = prm.param_defs(cfg)
    pspecs = prm.specs(defs, rules)
    aparams = prm.abstract(defs, cfg.dtype)
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((16, 64), jnp.int32)}
    from jax.sharding import PartitionSpec as P
    bspecs = {"tokens": P("data"), "labels": P("data")}
    step = training.make_train_step(cfg, TrainConfig(), 1, remat=True)
    ostate = inp.abstract_opt_state(cfg)
    with compat.set_mesh(mesh):
        c = jax.jit(step).lower(aparams, ostate, batch).compile()
    ma = c.memory_analysis()
    out[arch] = {"temp": ma.temp_size_in_bytes,
                 "flops": compat.cost_analysis(c).get("flops", 0.0)}
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, v in out.items():
        assert v["temp"] > 0 and v["flops"] > 0


def test_analytic_flops_scaling():
    """Analytic FLOPs must scale linearly in tokens and superlinearly never."""
    cfg = get_config("stablelm-3b")
    t = rl.analytic_flops(cfg, INPUT_SHAPES["train_4k"])
    p = rl.analytic_flops(cfg, INPUT_SHAPES["prefill_32k"])
    d = rl.analytic_flops(cfg, INPUT_SHAPES["decode_32k"])
    # train has bwd (~3x fwd-per-token) and 8x fewer ctx tokens than prefill
    assert t > 0 and p > 0 and d > 0
    assert d < t and d < p
    # decode flops per token ~= prefill flops per token at same ctx order
    per_tok_p = p / (32 * 32768)
    per_tok_d = d / 128
    assert 0.3 < per_tok_d / per_tok_p < 3.5


def test_analytic_close_to_model_flops():
    """Analytic >= 2*N*D (it adds the quadratic attention term, which at 32k
    context legitimately rivals the weight FLOPs) but within ~3x."""
    for name in ["stablelm-3b", "qwen2.5-3b"]:
        cfg = get_config(name)
        shape = INPUT_SHAPES["prefill_32k"]
        ana = rl.analytic_flops(cfg, shape)
        mf = rl.model_flops(cfg, shape)["model_flops"]
        assert 0.3 < mf / ana < 1.1, (name, mf / ana)


@pytest.mark.parametrize("name", ASSIGNED)
def test_every_arch_has_analytic_flops(name):
    cfg = get_config(name)
    for shape in INPUT_SHAPES.values():
        from repro.configs import shape_runnable
        if not shape_runnable(cfg, shape)[0]:
            continue
        assert rl.analytic_flops(cfg, shape) > 0
