"""End-to-end behaviour tests: the full RingAda training story on CPU."""
import os

import jax
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.core.unfreeze import boundary_schedule, UnfreezeSchedule
from repro.launch.train import train_pjit
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.checkpoint import checkpoint as ckpt


@pytest.mark.slow
def test_ringada_training_converges():
    """Scheduled unfreezing trains to lower loss than init, and the boundary
    actually moves during the run (paper Fig. 3(a) qualitative)."""
    cfg = get_config("mbert-squad").reduced()
    tc = TrainConfig(learning_rate=2e-3, batch_size=4, seq_len=64,
                     unfreeze_interval=8, warmup_steps=2)
    out = train_pjit(cfg, tc, steps=30, log_every=5, scheme="ringada",
                     log=lambda *a: None)
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert {h["boundary"] for h in hist} != {hist[0]["boundary"]}


@pytest.mark.slow
def test_ringada_vs_all_hot_same_data():
    """Both schemes must train; RingAda starts slower (fewer trainables) but
    the gap narrows — the paper's Fig. 3(a) observation."""
    cfg = get_config("mbert-squad").reduced()
    tc = TrainConfig(learning_rate=2e-3, batch_size=4, seq_len=64,
                     unfreeze_interval=6, warmup_steps=2)
    ring = train_pjit(cfg, tc, steps=24, log_every=4, scheme="ringada",
                      log=lambda *a: None)["history"]
    full = train_pjit(cfg, tc, steps=24, log_every=4, scheme="all_hot",
                      log=lambda *a: None)["history"]
    assert ring[-1]["loss"] < ring[0]["loss"]
    assert full[-1]["loss"] < full[0]["loss"]


@pytest.mark.slow
def test_checkpoint_resume_same_logits(tmp_path):
    cfg = get_config("stablelm-3b").reduced()
    tc = TrainConfig(batch_size=2, seq_len=32)
    out = train_pjit(cfg, tc, steps=4, scheme="ringada", log=lambda *a: None,
                     save_path=os.path.join(tmp_path, "ck"))
    params = out["params"]
    # fresh init + adapter-only restore reproduces the trained model exactly
    fresh = prm.materialize(prm.param_defs(cfg), jax.random.key(tc.seed),
                            cfg.dtype)
    restored, _ = ckpt.restore(os.path.join(tmp_path, "ck"), fresh)
    toks = jax.random.randint(jax.random.key(7), (1, 32), 0, cfg.vocab_size)
    a, _ = tfm.forward(params, toks, cfg)
    b, _ = tfm.forward(restored, toks, cfg)
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_staged_recompile_count():
    """One jit entry per distinct boundary — the staged re-jit contract."""
    cfg = get_config("mbert-squad").reduced(n_layers=4, repeats=4)
    segs = boundary_schedule(cfg, UnfreezeSchedule(1, 10), 35)
    boundaries = [b for (_, _, b) in segs]
    assert boundaries == [3, 2, 1, 0]


def test_serve_batch_end_to_end():
    from repro.launch.serve import BatchServer, Request
    cfg = get_config("qwen2.5-3b").reduced()
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=5 + i
                                    ).astype(np.int32), 4) for i in range(4)]
    srv = BatchServer(cfg, params, slots=2, horizon=32)
    res = srv.run(reqs, log=lambda *a: None)
    assert set(res) == {0, 1, 2, 3}
    assert all(len(v) == 4 for v in res.values())
