"""RingAda's core mechanism: scheduled unfreezing + truncated backprop."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.core.unfreeze import (UnfreezeSchedule, boundary_schedule,
                                 depth_to_boundary)
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.optim import adamw


def test_schedule_matches_algorithm1():
    # paper: start d=1 (head + top adapter), every k=40 steps d += 1
    s = UnfreezeSchedule(initial_depth=1, interval=40)
    assert s.depth_at(0, 12) == 1
    assert s.depth_at(39, 12) == 1
    assert s.depth_at(40, 12) == 2
    assert s.depth_at(400, 12) == 11
    assert s.depth_at(4000, 12) == 12       # capped at n_layers


def test_schedule_explicit_depths():
    s = UnfreezeSchedule(interval=10, depths=(1, 2, 5))
    assert s.depth_at(0, 12) == 1
    assert s.depth_at(19, 12) == 2
    assert s.depth_at(25, 12) == 5
    assert s.depth_at(9999, 12) == 5          # last entry holds forever
    assert s.depth_at(25, 3) == 3             # capped at n_blocks


def test_schedule_rejects_non_monotone():
    """The activation cache's invalidation contract: boundary never increases,
    i.e. depth never shrinks. Anything else must fail loudly at construction."""
    with pytest.raises(ValueError, match="non-monotone"):
        UnfreezeSchedule(interval=10, depths=(1, 3, 2))
    with pytest.raises(ValueError, match="interval"):
        UnfreezeSchedule(interval=0)
    with pytest.raises(ValueError, match="initial_unfreeze_depth"):
        UnfreezeSchedule(initial_depth=0)
    with pytest.raises(ValueError, match="depths"):
        UnfreezeSchedule(depths=())


def test_boundary_schedule_rejects_rising_boundary():
    """Defense-in-depth: even a custom depth_at that shrinks depth mid-run is
    caught when the segments are materialized."""
    class Bad(UnfreezeSchedule):
        def depth_at(self, step, n_blocks):
            return 3 if step < 5 else 1        # depth shrinks: boundary rises

    cfg = get_config("mbert-squad").reduced(n_layers=4, repeats=4)
    with pytest.raises(ValueError, match="non-monotone"):
        boundary_schedule(cfg, Bad(), 20)


def test_depth_to_boundary_uniform():
    cfg = get_config("stablelm-3b")
    assert depth_to_boundary(cfg, 1) == 31
    assert depth_to_boundary(cfg, 32) == 0


def test_depth_to_boundary_patterned():
    cfg = get_config("llama-3.2-vision-11b")   # 5 layers per repeat, 8 repeats
    assert depth_to_boundary(cfg, 1) == 7       # rounds up to one superblock
    assert depth_to_boundary(cfg, 5) == 7
    assert depth_to_boundary(cfg, 6) == 6
    assert depth_to_boundary(cfg, 40) == 0


def test_boundary_schedule_segments():
    cfg = get_config("mbert-squad").reduced(n_layers=4, repeats=4)
    segs = boundary_schedule(cfg, UnfreezeSchedule(1, 10), 40)
    assert segs[0] == (0, 10, 3)
    assert segs[1] == (10, 20, 2)
    assert segs[-1][2] == 0
    # segments tile [0, total) exactly
    assert segs[0][0] == 0 and segs[-1][1] == 40
    for (a, b, _), (c, d, _) in zip(segs, segs[1:]):
        assert b == c


def _setup(n_layers=6):
    cfg = get_config("stablelm-3b").reduced(n_layers=n_layers, repeats=n_layers)
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 64), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                     cfg.vocab_size),
    }
    return cfg, params, batch


def test_forward_invariant_to_boundary():
    cfg, params, batch = _setup()
    outs = [tfm.forward(params, batch["tokens"], cfg, boundary=b)[0]
            for b in (0, 3, 6)]
    for o in outs[1:]:
        assert jnp.allclose(outs[0].astype(jnp.float32),
                            o.astype(jnp.float32), atol=1e-2)


@pytest.mark.slow
def test_activation_memory_shrinks_with_boundary():
    """The paper's memory claim: frozen trunk stores no residuals.

    Asserts the robust form — any frozen trunk cuts temp memory well below the
    full-backward step.  (Strict monotonicity BETWEEN frozen depths is an XLA
    scheduling artifact: e.g. on jaxlib 0.4.36/CPU, b=5 allocates slightly
    more temp than b=3 while both sit at ~1/3 of b=0.)"""
    cfg, params, batch = _setup()
    tc = TrainConfig()
    opt = adamw.init(training.full_trainable(params))
    temps = []
    for b in (0, 3, 5):
        step = jax.jit(training.make_train_step(cfg, tc, b))
        c = step.lower(params, opt, batch).compile()
        temps.append(c.memory_analysis().temp_size_in_bytes)
    assert temps[1] < 0.6 * temps[0], temps
    assert temps[2] < 0.6 * temps[0], temps


def test_grads_zero_below_boundary_nonzero_above():
    cfg, params, batch = _setup()
    # make adapters non-trivial so grads flow
    e = params["blocks"][0]["adapter"]
    e["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), e["w_up"].shape,
                                         jnp.float32).astype(e["w_up"].dtype)
    b = 3

    def loss_fn(tr):
        logits, _ = tfm.forward(params, batch["tokens"], cfg, boundary=b,
                                hot_adapters=tr["adapters"],
                                head_params=tr["head"])
        return jnp.sum(logits.astype(jnp.float32) ** 2)

    tr = training.split_trainable(params, b)
    g = jax.grad(loss_fn)(tr)
    hot = g["adapters"][0]["w_up"]
    assert hot.shape[0] == cfg.repeats - b
    assert float(jnp.abs(hot).max()) > 0
    assert float(jnp.abs(g["head"]["w"]).max()) > 0


def test_frozen_adapter_is_identity():
    """Zero-init W_up => untouched adapters compute the identity (the paper's
    'deactivated' bottom adapters)."""
    from repro.core.adapter import apply_adapter
    D, m = 32, 8
    p = {"w_down": jax.random.normal(jax.random.key(0), (D, m), jnp.float32),
         "w_up": jnp.zeros((m, D), jnp.float32)}
    h = jax.random.normal(jax.random.key(1), (4, D), jnp.float32)
    assert jnp.array_equal(apply_adapter(p, h), h)
