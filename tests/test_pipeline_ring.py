"""Ring pipeline (shard_map + ppermute): run in a 4-device subprocess.

shard_map needs real (host) devices; the main pytest process keeps the default
1-device backend, so these tests re-exec themselves with
XLA_FLAGS=--xla_force_host_platform_device_count=4.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core.pipeline import pipeline_tick_counts

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config
from repro.models import params as P, transformer as T
from repro.core import pipeline as pl, training
from repro.models.losses import cross_entropy

cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4)
params = P.materialize(P.param_defs(cfg), jax.random.key(0))
ad = params["blocks"][0]["adapter"]
ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                      jnp.float32).astype(ad["w_up"].dtype)
mesh = compat.make_mesh((4,), ("stage",))
S, M, mb, seq = 4, 3, 2, 32
tokens = jax.random.randint(jax.random.key(1), (S, M, mb, seq), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (S, M, mb, seq), 0, cfg.vocab_size)
stage_blocks, shared = pl.stage_stack(params, cfg, S)
"""


@pytest.mark.slow
def test_ring_loss_matches_reference_all_owners():
    code = PRELUDE + """
res = {}
with compat.set_mesh(mesh):
    for owner in range(4):
        fn = jax.jit(pl.make_ring_round(cfg, mesh, n_stages=S, owner=owner,
                                        boundary=0, n_micro=M))
        loss = fn(stage_blocks, shared, tokens, labels)
        ts = tokens[owner].reshape(M * mb, seq)
        ls = labels[owner].reshape(M * mb, seq)
        logits, _ = T.forward(params, ts, cfg)
        ref, _ = cross_entropy(logits, ls)
        res[str(owner)] = [float(loss), float(ref)]
print(json.dumps(res))
"""
    res = _run_sub(code)
    for owner, (got, want) in res.items():
        assert abs(got - want) < 3e-3, (owner, got, want)


@pytest.mark.slow
def test_ring_grads_match_pjit_path():
    code = PRELUDE + """
owner, boundary = 1, 2
with compat.set_mesh(mesh):
    fn = jax.jit(pl.make_ring_train_round(cfg, mesh, n_stages=S, owner=owner,
                                          boundary=boundary, n_micro=M))
    loss, (gad, ghead) = fn(stage_blocks, shared, tokens, labels)
ts = tokens[owner].reshape(M * mb, seq)
ls = labels[owner].reshape(M * mb, seq)
def loss_fn(tr):
    logits, _ = T.forward(params, ts, cfg, boundary=boundary,
                          hot_adapters=tr["adapters"], head_params=tr["head"])
    return cross_entropy(logits, ls)[0]
tr = training.split_trainable(params, boundary)
ref = jax.grad(loss_fn)(tr)
ra = ref["adapters"][0]["w_up"]
ga = gad["w_up"].reshape(4, *gad["w_up"].shape[2:])[boundary:]
err_ad = float(jnp.abs(ra.reshape(ga.shape).astype(jnp.float32)
                       - ga.astype(jnp.float32)).max())
err_hd = float(jnp.abs(ref["head"]["w"].astype(jnp.float32)
                       - ghead["w"].astype(jnp.float32)).max())
frozen_zero = bool((gad["w_up"][:boundary] == 0).all())
print(json.dumps({"err_ad": err_ad, "err_hd": err_hd,
                  "frozen_zero": frozen_zero}))
"""
    res = _run_sub(code)
    assert res["err_ad"] < 5e-3
    assert res["err_hd"] < 5e-3
    assert res["frozen_zero"]


@pytest.mark.slow
def test_ring_trainer_rounds_reduce_loss():
    code = PRELUDE + """
from repro.configs import TrainConfig
from repro.core.ring import RingTrainer
from repro.data.pipeline import make_client_datasets, RingBatcher
tc = TrainConfig(learning_rate=3e-3, unfreeze_interval=4, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
trainer = RingTrainer(cfg, tc, mesh, params, S, M)
clients = make_client_datasets(S, vocab=cfg.vocab_size, n_per_client=32,
                               seq=seq, seed=0)
rb = RingBatcher(clients, M, mb, seed=0)
losses = []
with compat.set_mesh(mesh):
    for r in range(6):
        tk, lb = rb.next()
        m = trainer.round(tk, lb)
        losses.append(m["loss"])
print(json.dumps({"losses": losses}))
"""
    res = _run_sub(code)
    assert res["losses"][-1] < res["losses"][0]


def test_ring_round_local_matches_static_owner_round():
    """The traced-owner round (and therefore the phase_a/phase_b split it is
    composed from — the same halves the fused executor runs) reproduces the
    static-owner reference ``make_ring_round`` for every owner."""
    code = PRELUDE + """
from jax.sharding import PartitionSpec as Pspec
boundary = 2
local = pl.ring_round_local(cfg, n_stages=S, boundary=boundary, n_micro=M)

def global_local_round(owner, stage_blocks, shared, tokens, labels):
    def body(owner, stage_blocks, shared, tokens, labels):
        my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)
        my_tokens = tokens[0]
        seq_ = my_tokens.shape[2]
        mb_ = my_tokens.shape[1]
        pos = jnp.broadcast_to(jnp.arange(seq_, dtype=jnp.int32)[None],
                               (mb_, seq_))
        shared_rest = {k: v for k, v in shared.items() if k != "head"}
        emb_g = pl.gather_embeddings(cfg, shared_rest, my_tokens, pos)
        l_loc = local(owner, my_blocks, shared, emb_g, labels[0])
        return jax.lax.psum(l_loc, "stage")
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(Pspec(), Pspec("stage"), Pspec(), Pspec("stage"),
                  Pspec("stage")),
        out_specs=Pspec())(owner, stage_blocks, shared, tokens, labels)

res = {}
with compat.set_mesh(mesh):
    fused = jax.jit(global_local_round)
    for owner in range(4):
        ref_fn = jax.jit(pl.make_ring_round(cfg, mesh, n_stages=S, owner=owner,
                                            boundary=boundary, n_micro=M))
        ref = ref_fn(stage_blocks, shared, tokens, labels)
        got = fused(jnp.int32(owner), stage_blocks, shared, tokens, labels)
        res[str(owner)] = [float(got), float(ref)]
print(json.dumps(res))
"""
    res = _run_sub(code)
    for owner, (got, want) in res.items():
        assert abs(got - want) < 1e-4, (owner, got, want)


def test_tick_counts():
    # PipeAdapter: fwd/bwd both M+S-1; RingAda shrinks bwd by frozen stages
    t0 = pipeline_tick_counts(4, 8, boundary=0, lps=1)
    assert t0["bwd_ticks"] == 11
    t2 = pipeline_tick_counts(4, 8, boundary=2, lps=1)
    assert t2["bwd_ticks"] == 9
    assert t2["frozen_stages"] == 2
    t3 = pipeline_tick_counts(4, 8, boundary=3, lps=1)
    assert t3["bwd_ticks"] == 8
    # actcache steady state: Phase A's M+F-1 ticks vanish, backward unchanged
    t2c = pipeline_tick_counts(4, 8, boundary=2, lps=1, cached=True)
    assert t2c["fwd_ticks"] == t2["fwd_ticks"] - (8 + 2 - 1)
    assert t2c["bwd_ticks"] == t2["bwd_ticks"]
    assert pipeline_tick_counts(4, 8, boundary=0, lps=1, cached=True) == {
        **t0, "fwd_ticks": t0["fwd_ticks"]}
