"""Heterogeneous ring execution: `assign_layers` partitions run for REAL.

The paper's coordinator assigns *uneven* contiguous block spans to
heterogeneous edge devices (Algorithm 1; the 4:5:2:3 example).  This module
is the differential harness between the three places that model/execute a
span layout:

  (a) closed forms   — ``pipeline.pipeline_tick_counts(spans=...)``,
  (b) the simulator  — ``simulator.spmd_tick_round`` (discrete-event engine
      in the SPMD executor's tick units),
  (c) the executor   — ``RingExecutor.measured_tick_ledger`` (the scan
      lengths XLA actually traced into the round executables),

plus the numerics contracts of heterogeneous execution:

  (d) loss/param equivalence — any span layout realizes the SAME function
      per microbatch (stages apply the same blocks in the same order), so
      ragged fused/cached/packed executors must match the uniform-partition
      oracle at the established 1e-5 / 1e-3 pins whenever the layouts share
      the aligned unfreeze boundary,
  (e) the partitioner itself — coverage, contiguity, memory feasibility and
      bottleneck-optimality vs brute force (deterministic; the hypothesis
      versions live in tests/test_property.py),
  (f) repartitioning — ``RingExecutor.repartition`` preserves numerics and
      flushes the activation cache (span-layout invalidation).
"""
import itertools
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.partition import (DeviceProfile, align_boundary,
                                  assign_layers, frozen_stage_count,
                                  normalize_spans, parse_device_profiles,
                                  span_boundaries, span_sizes,
                                  spans_from_profiles, uniform_assignment)
from repro.core.pipeline import pipeline_tick_counts
from repro.core.simulator import spmd_tick_round

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# (e) partitioner: layout helpers + uniform fallback
# ---------------------------------------------------------------------------


def test_uniform_assignment_divisible_unchanged():
    assert uniform_assignment(12, 4) == [(0, 3), (3, 6), (6, 9), (9, 12)]


def test_uniform_assignment_ragged_fallback():
    """n_blocks % n_stages != 0 no longer crashes: most balanced split,
    larger spans first, still a contiguous cover."""
    assert uniform_assignment(14, 4) == [(0, 4), (4, 8), (8, 11), (11, 14)]
    assert uniform_assignment(5, 2) == [(0, 3), (3, 5)]
    assert uniform_assignment(7, 7) == [(i, i + 1) for i in range(7)]
    for n, u in ((9, 4), (13, 3), (17, 5)):
        spans = uniform_assignment(n, u)
        sizes = span_sizes(spans)
        assert spans[0][0] == 0 and spans[-1][1] == n
        assert max(sizes) - min(sizes) <= 1          # most balanced
        assert sorted(sizes, reverse=True) == list(sizes)


def test_normalize_spans_sizes_and_pairs():
    want = ((0, 4), (4, 9), (9, 11), (11, 14))
    assert normalize_spans([4, 5, 2, 3]) == want
    assert normalize_spans(want, 14) == want
    with pytest.raises(ValueError, match="contiguous"):
        normalize_spans([(0, 4), (5, 9)])            # gap
    with pytest.raises(ValueError, match="contiguous"):
        normalize_spans([(0, 4), (2, 9)])            # overlap
    with pytest.raises(ValueError, match="contiguous"):
        normalize_spans([(0, 4), (4, 4)])            # empty span
    with pytest.raises(ValueError, match="covers"):
        normalize_spans([4, 5, 2, 3], 15)            # wrong model size


def test_align_boundary_and_frozen_count():
    sp = normalize_spans([4, 5, 2, 3])
    assert span_boundaries(sp) == (0, 4, 9, 11, 14)
    for raw, aligned, f in ((0, 0, 0), (3, 0, 0), (4, 4, 1), (8, 4, 1),
                            (9, 9, 2), (10, 9, 2), (11, 11, 3), (13, 11, 3)):
        assert align_boundary(sp, raw) == aligned
        assert frozen_stage_count(sp, aligned) == f
    with pytest.raises(ValueError, match="not span-aligned"):
        frozen_stage_count(sp, 5)


def test_assign_layers_paper_example():
    """Speeds skewed as 1.0 : 1.25 : 0.5 : 0.75 over 14 uniform blocks give
    the paper's 4:5:2:3 assignment (speed-proportional spans)."""
    profiles = parse_device_profiles([1.0, 1.25, 0.5, 0.75])
    assert span_sizes(spans_from_profiles(14, profiles)) == (4, 5, 2, 3)


# -- brute-force optimality ---------------------------------------------------


def _brute_force_bottleneck(costs, mems, devs):
    """Min bottleneck over ALL contiguous partitions that fit memory."""
    n, u = len(costs), len(devs)
    best = None
    for cuts in itertools.combinations(range(1, n), u - 1):
        edges = (0,) + cuts + (n,)
        t = 0.0
        ok = True
        for i, dev in enumerate(devs):
            a, b = edges[i], edges[i + 1]
            if sum(mems[a:b]) > dev.memory_mb:
                ok = False
                break
            t = max(t, sum(costs[a:b]) / dev.compute_speed)
        if ok and (best is None or t < best):
            best = t
    return best


@pytest.mark.parametrize("seed", range(8))
def test_assign_layers_bottleneck_optimal_vs_brute_force(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 10))
    u = int(rng.integers(2, min(n, 4) + 1))
    costs = rng.uniform(0.2, 2.0, n).tolist()
    mems = rng.uniform(0.5, 2.0, n).tolist()
    devs = [DeviceProfile(compute_speed=float(rng.uniform(0.3, 2.0)),
                          memory_mb=float(rng.uniform(2.5, 8.0)))
            for _ in range(u)]
    want = _brute_force_bottleneck(costs, mems, devs)
    if want is None:
        with pytest.raises(ValueError, match="memory"):
            assign_layers(costs, mems, devs)
        return
    spans = assign_layers(costs, mems, devs)
    # coverage + contiguity + memory feasibility
    assert normalize_spans(spans, n) == tuple(spans)
    for (a, b), dev in zip(spans, devs):
        assert sum(mems[a:b]) <= dev.memory_mb + 1e-12
    got = max(sum(costs[a:b]) / dev.compute_speed
              for (a, b), dev in zip(spans, devs))
    assert got <= want * (1 + 1e-9) + 1e-12, (spans, got, want)


def test_assign_layers_memory_forces_smaller_spans():
    """A fast device with a tiny memory budget cannot hog blocks: memory
    caps its span even though speed alone would give it everything."""
    costs, mems = [1.0] * 6, [1.0] * 6
    fast_small = DeviceProfile(compute_speed=100.0, memory_mb=2.0)
    slow_big = DeviceProfile(compute_speed=1.0, memory_mb=100.0)
    spans = assign_layers(costs, mems, [fast_small, slow_big])
    assert span_sizes(spans)[0] == 2                 # memory-capped
    with pytest.raises(ValueError, match="memory"):
        assign_layers(costs, mems,
                      [DeviceProfile(1.0, 2.0), DeviceProfile(1.0, 2.0)])


# ---------------------------------------------------------------------------
# (a) vs (b): closed forms vs the discrete-event engine, uneven spans
# ---------------------------------------------------------------------------

LAYOUT_GRID = ([4, 5, 2, 3], [1, 1, 1, 1], [2, 1], [3, 1, 1, 2],
               [5, 1, 1, 1], [1, 6, 4, 3])


@pytest.mark.parametrize("layout", LAYOUT_GRID,
                         ids=[":".join(map(str, l)) for l in LAYOUT_GRID])
def test_sim_ticks_match_closed_forms_uneven_spans(layout):
    """The engine's makespan in SPMD tick units equals
    ``pipeline_tick_counts(spans=...)`` for every alignable boundary with a
    terminator, scanned and packed, across microbatch counts."""
    sp = normalize_spans(layout)
    S = len(sp)
    for boundary in span_boundaries(sp)[:-1]:        # F < S
        for M in (1, 2, 4):
            for packed in (False, True):
                want = pipeline_tick_counts(S, M, boundary=boundary,
                                            spans=sp, packed=packed)
                got = spmd_tick_round(sp, M, boundary, packed=packed)
                assert got["phase_a_round_ticks"] == \
                    want["phase_a_round_ticks"], (layout, boundary, M, packed)
                assert got["frozen_stages"] == want["frozen_stages"]
            cached = spmd_tick_round(sp, M, boundary, cached=True)
            assert cached["phase_a_round_ticks"] == 0


def test_span_tick_counts_equal_lps_form_when_uniform():
    for S, M, lps in ((4, 8, 3), (2, 4, 2), (4, 1, 1)):
        sp = [lps] * S
        for f in range(S):
            for kw in ({}, {"packed": True}, {"cached": True}):
                assert pipeline_tick_counts(S, M, boundary=f * lps,
                                            lps=lps, **kw) == \
                    pipeline_tick_counts(S, M, boundary=f * lps,
                                         spans=sp, **kw)


# ---------------------------------------------------------------------------
# (c) + (d): executor differential — 4-device subprocess
# ---------------------------------------------------------------------------

PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.models import params as P
from repro.core.executor import RingExecutor
from repro.core.ring import RingTrainer
from repro.core.pipeline import pipeline_tick_counts
from repro.core.simulator import spmd_tick_round

cfg = get_config("stablelm-3b").reduced(n_layers=14, repeats=14,
                                        d_model=64, d_ff=128, vocab_size=128)
S, M, mb, seq = 4, 2, 1, 16

def fresh_params():
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

mesh = compat.make_mesh((S,), ("stage",))

def batch(k=0):
    t = jax.random.randint(jax.random.key(10 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    l = jax.random.randint(jax.random.key(20 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    return t, l

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_hetero_executor_matches_uniform_oracle_and_tick_ledger():
    """The headline acceptance test: 4:5:2:3 (and friends) train end-to-end
    on the 4-device mesh.

    All layouts share aligned boundary 11 (depth 3), so they compute the
    SAME function: losses/params must match the balanced-layout fused oracle
    at 1e-5 / 1e-3 — for the plain ragged executor, the per-owner-scan
    (packed=False) variant, AND the cached (Phase-A-skip) variant.  Each
    executor's measured tick ledger (the scan lengths XLA actually traced)
    must equal the closed forms AND the discrete-event simulator exactly.
    """
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
batches = [batch(0), batch(1)]
out = {}
with compat.set_mesh(mesh):
    oracle = RingExecutor(cfg, tc, mesh, fresh_params(), S, M)  # 4:4:3:3
    o_losses = []
    for r in range(4):
        t, l = batches[r % 2]
        o_losses.append(
            RingExecutor.materialize_metrics(oracle.round(t, l))["loss"])
    op = oracle.export_params()
    out["oracle_boundary"] = oracle.boundary_at(0)
    for name, kw in (
            ("4:5:2:3", dict(spans=[4, 5, 2, 3])),
            ("4:5:2:3/scan", dict(spans=[4, 5, 2, 3], packed=False)),
            ("2:4:5:3", dict(spans=[2, 4, 5, 3])),
            ("4:5:2:3/cached", dict(spans=[4, 5, 2, 3], cache_capacity=2)),
    ):
        cap = kw.get("cache_capacity", 0)
        drv = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, **kw)
        losses, hits = [], []
        for r in range(4):
            t, l = batches[r % 2]
            m = RingExecutor.materialize_metrics(
                drv.round(t, l, slot=r % 2 if cap else None))
            losses.append(m["loss"])
            hits.append(m.get("cache_hit", False))
        b = drv.boundary_at(0)
        mode = "cached" if cap else "direct"
        led = drv.measured_tick_ledger(b, mode)
        packed_eff = (drv.packed and mode != "cached"
                      and led["frozen_stages"] >= 2)
        want = pipeline_tick_counts(S, M, boundary=b, spans=drv.spans,
                                    packed=packed_eff, cached=mode == "cached")
        sim = spmd_tick_round(drv.spans, M, b, packed=packed_eff,
                              cached=mode == "cached")
        out[name] = {
            "b": b, "losses": losses, "hits": hits,
            "param_err": maxerr(op, drv.export_params()),
            "loss_err": max(abs(a - c) for a, c in zip(o_losses, losses)),
            "ledger": led, "closed": want,
            "sim_phase_a": sim["phase_a_round_ticks"],
            "capture_ledger": (drv.measured_tick_ledger(b, "capture")
                               if cap else None),
        }
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res.pop("oracle_boundary") == 11
    for name, rec in res.items():
        # (d) same function as the uniform oracle: established pins hold
        assert rec["b"] == 11, (name, rec)
        assert rec["loss_err"] < 1e-5, (name, rec)
        assert rec["param_err"] < 1e-3, (name, rec)
        # (c) measured scan lengths == closed forms == discrete-event engine
        led, want = rec["ledger"], rec["closed"]
        assert led == want, (name, led, want)
        assert led["phase_a_round_ticks"] == rec["sim_phase_a"], (name, rec)
        if name.endswith("/cached"):
            assert rec["hits"] == [False, False, True, True], (name, rec)
            assert led["phase_a_round_ticks"] == 0
            # the capture executable still pays full Phase A
            cap = rec["capture_ledger"]
            assert cap["phase_a_round_ticks"] > 0, (name, cap)


def test_hetero_boundary_walk_fused_vs_reference():
    """Walking the unfreeze schedule on a ragged layout: the fused executor
    and the unfused RingTrainer oracle align boundaries identically
    (span edges, not lps multiples) and stay loss/param-equivalent."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=2 * S,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
spans = [4, 5, 2, 3]
tokens, labels = batch(0)
out = {"fused": [], "ref": [], "b": []}
with compat.set_mesh(mesh):
    fused = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, spans=spans)
    ref = RingTrainer(cfg, tc, mesh, fresh_params(), S, M, spans=spans)
    for r in range(6):
        mf = RingExecutor.materialize_metrics(fused.round(tokens, labels))
        mr = ref.round(tokens, labels)
        out["fused"].append(mf["loss"])
        out["ref"].append(mr["loss"])
        assert mf["boundary"] == mr["boundary"], (mf, mr)
        out["b"].append(mf["boundary"])
    out["param_err"] = maxerr(fused.export_params(), ref.export_params())
print(json.dumps(out))
"""
    res = _run_sub(code)
    # depth 3 -> b=11 aligned; depth walks 3,4,5,6,... -> raw 11,10,9,8 ->
    # aligned 11,9,9,4 at rounds (interval = 2 rounds)
    assert res["b"][0] == 11 and res["b"][-1] < 11
    assert sorted(res["b"], reverse=True) == res["b"]      # monotone drop
    for fl, rl in zip(res["fused"], res["ref"]):
        assert abs(fl - rl) < 1e-5, res
    assert res["param_err"] < 1e-3


def test_repartition_preserves_numerics_and_flushes_cache():
    """(f): mid-run repartition balanced -> 4:5:2:3 keeps training
    loss-identical to a never-repartitioned uncached oracle (params + Adam
    moments restack exactly), while the activation cache does a whole-cache
    span-layout invalidation and re-captures."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
batches = [batch(0), batch(1)]
out = {"plain": [], "repart": [], "hits": []}
with compat.set_mesh(mesh):
    plain = RingExecutor(cfg, tc, mesh, fresh_params(), S, M)
    drv = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, cache_capacity=2)
    for r in range(8):
        if r == 4:
            drv.repartition([4, 5, 2, 3])
            out["layout_inval"] = drv.cache.invalidations
        t, l = batches[r % 2]
        mp = RingExecutor.materialize_metrics(plain.round(t, l))
        mc = RingExecutor.materialize_metrics(drv.round(t, l, slot=r % 2))
        out["plain"].append(mp["loss"])
        out["repart"].append(mc["loss"])
        out["hits"].append(mc["cache_hit"])
    out["param_err"] = maxerr(plain.export_params(), drv.export_params())
    out["stats"] = drv.cache.stats()
    out["spans"] = [list(sp) for sp in drv.spans]
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["spans"] == [[0, 4], [4, 9], [9, 11], [11, 14]]
    # capture, capture, hit, hit -- repartition -- capture, capture, hit, hit
    assert res["hits"] == [False, False, True, True] * 2, res
    assert res["layout_inval"] == 1                      # span-layout flush
    for pl, rl in zip(res["plain"], res["repart"]):
        assert abs(pl - rl) < 1e-5, res
    assert res["param_err"] < 1e-3
    assert res["stats"]["cache_invalidations"] == 1


def test_session_hetero_checkpoint_roundtrip():
    """RingSession.create(device_profiles=...) derives the 4:5:2:3 layout,
    trains, saves; restore rebuilds the SAME spans from the checkpoint (no
    CLI flags needed) and continues with identical losses.  Restoring into a
    mismatched explicit layout fails the format check loudly."""
    code = PRELUDE + """
import os, tempfile
from repro.api import RingSession
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
path = os.path.join(tempfile.mkdtemp(), "het_ck")
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                          device_profiles=[1.0, 1.25, 0.5, 0.75])
spans0 = [list(sp) for sp in sess.backend.spans]
sess.run(2)
sess.save(path)
cont = [h["loss"] for h in sess.run(3)]
restored = RingSession.restore(path, cfg, tc)
again = [h["loss"] for h in restored.run(3)]
bad = None
try:
    RingSession.restore(path, cfg, tc, spans=[3, 4, 3, 4])
except ValueError as e:
    bad = str(e)
print(json.dumps({"spans0": spans0,
                  "spans1": [list(sp) for sp in restored.backend.spans],
                  "cont": cont, "again": again, "bad": bad}))
"""
    res = _run_sub(code)
    assert res["spans0"] == [[0, 4], [4, 9], [9, 11], [11, 14]]
    assert res["spans1"] == res["spans0"]          # layout rode the ckpt
    assert res["cont"] == res["again"], res
    assert res["bad"] and "format" in res["bad"], res
