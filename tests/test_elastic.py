"""Elastic ring under churn: the chaos-mode differential harness.

Extends the PR-5 methodology (closed forms == discrete-event simulator ==
measured executor ledgers, exactly) to fleet churn:

  (a) churn replay     — ``ChurnEvent`` validation, ``apply_churn``,
      ``simulate_training(churn=...)`` re-pricing recovery rounds;
  (b) detection        — ``StragglerDetector`` EWMA re-fit + hysteresis:
      a stable skewed mesh triggers at most ONE repartition (no flapping);
  (c) recovery         — ``RingExecutor.shrink``: post-shrink measured tick
      ledgers equal ``spmd_tick_round`` / ``predict_recovery`` EXACTLY, and
      post-shrink training matches a from-scratch S-1 ring (same transplanted
      params + Adam moments) at the established 1e-5 / 1e-3 pins — the
      checkpoint-free recovery claim, as a differential;
  (d) the chaos gate   — ``ChaosBackend`` through ``RingSession``: a
      mid-schedule kill completes training with no checkpoint restore,
      save -> resume across a shrink is bit-reproducible, a non-elastic
      crash raises, a rejoin grows the ring back.

Subprocess tests need 4 CPU devices (XLA_FLAGS host platform override).
"""
import json
import math
import os
import subprocess
import sys

import pytest

from repro.core.elastic import StragglerDetector, parse_chaos_events
from repro.core.partition import (DeviceProfile, normalize_spans,
                                  parse_device_profiles, span_sizes)
from repro.core.simulator import (ChurnEvent, LayerProfile, SimConfig,
                                  apply_churn, full_round_ticks,
                                  predict_recovery, simulate_training)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# (a) churn events: validation, parsing, fleet replay
# ---------------------------------------------------------------------------


def test_churn_event_validation():
    ChurnEvent(round=0, kind="crash", device=0)        # ok
    with pytest.raises(ValueError, match="unknown churn kind"):
        ChurnEvent(round=0, kind="explode", device=0)
    with pytest.raises(ValueError, match=">= 0"):
        ChurnEvent(round=-1, kind="crash", device=0)
    with pytest.raises(ValueError, match=">= 0"):
        ChurnEvent(round=0, kind="crash", device=-2)
    with pytest.raises(ValueError, match="factor"):
        ChurnEvent(round=0, kind="slowdown", device=0, factor=0.0)


def test_parse_chaos_events():
    evs = parse_chaos_events(["5:slowdown:1:4.0", "3:crash:2", "7:JOIN:2"])
    assert [e.round for e in evs] == [3, 5, 7]         # sorted by round
    assert evs[0] == ChurnEvent(round=3, kind="crash", device=2)
    assert evs[1].factor == 4.0
    assert evs[2].kind == "join"                        # case-insensitive
    for bad in ("3:crash", "a:crash:2", "3:crash:x", "3:crash:2:z",
                "3:explode:2", "1:2:3:4:5"):
        with pytest.raises(ValueError, match="chaos spec"):
            parse_chaos_events([bad])


def test_apply_churn_fleet_replay():
    fleet = parse_device_profiles([1.0, 1.25, 0.5, 0.75])
    f2 = apply_churn(fleet, ChurnEvent(round=0, kind="crash", device=2))
    assert [p.compute_speed for p in f2] == [1.0, 1.25, 0.75]
    assert len(fleet) == 4                              # input untouched
    f3 = apply_churn(f2, ChurnEvent(round=1, kind="slowdown", device=0,
                                    factor=2.0))
    assert f3[0].compute_speed == 0.5
    f4 = apply_churn(f3, ChurnEvent(round=2, kind="join", device=2,
                                    profile=DeviceProfile(0.5, 100.0)))
    assert len(f4) == 4 and f4[2].compute_speed == 0.5
    with pytest.raises(ValueError, match="fleet has"):
        apply_churn(f2, ChurnEvent(round=0, kind="crash", device=7))
    one = [DeviceProfile(1.0, float("inf"))]
    with pytest.raises(ValueError, match="last device"):
        apply_churn(one, ChurnEvent(round=0, kind="leave", device=0))


def _unit_layers(n):
    return [LayerProfile(fwd_s=1.0, bwd_s=1.0, act_mb=1.0, weight_mb=1.0,
                         adapter_mb=0.1, boundary_mb=0.0) for _ in range(n)]


def test_simulate_training_replays_churn():
    """A crash mid-run shrinks the simulated fleet (later rounds run on the
    survivors' speed-weighted spans) and resets the cached scheme's capture
    counter: the first post-crash round is priced as a full capture round."""
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=4)
    devs = parse_device_profiles([1.0, 1.0, 1.0, 1.0])
    kw = dict(rounds=6, unfreeze_interval=10**6, initial_depth=3,
              slots_per_epoch=1)
    tot_plain, _, times_plain = simulate_training("ringada_cached", sim,
                                                  _unit_layers(12), devs, **kw)
    churn = [ChurnEvent(round=3, kind="crash", device=1)]
    tot_churn, _, times = simulate_training("ringada_cached", sim,
                                            _unit_layers(12), devs,
                                            churn=churn, **kw)
    assert len(times) == 6
    per_round = [t - p for t, p in zip(times, [0.0] + times[:-1])]
    # rounds 0-2 identical to the no-churn run; round 3 re-pays capture
    per_plain = [t - p for t, p in zip(times_plain, [0.0] + times_plain[:-1])]
    assert per_round[:3] == pytest.approx(per_plain[:3])
    assert per_round[3] > per_round[2]                 # recovery > steady
    assert per_round[4] < per_round[3]                 # cache refilled
    with pytest.raises(TypeError, match="ChurnEvent"):
        simulate_training("ringada", sim, _unit_layers(12), devs,
                          churn=["3:crash:1"], **kw)


def test_predict_recovery_closed_forms():
    """S=4, M=2, F=2 packed: recovery = (S*M + F - 1) + S*2*(M + hot - 1)
    = 9 + 24 = 33 ticks; steady cached = 24 — recovery <= 2x steady, the
    invariant the elastic bench gates."""
    survivors = parse_device_profiles([1.0, 1.0, 1.0, 1.0])
    pred = predict_recovery(8, survivors, 2, boundary=4, slots_per_epoch=3)
    assert span_sizes(pred["spans"]) == (2, 2, 2, 2)
    assert pred["boundary"] == 4 and pred["frozen_stages"] == 2
    assert pred["recovery_round_ticks"] == 4 * 2 + 2 - 1 + 4 * 2 * (2 + 2 - 1)
    assert pred["steady_round_ticks"] == 4 * 2 * (2 + 2 - 1)
    assert pred["rounds_to_cache_refill"] == 3
    assert pred["recovery_round_ticks"] <= 2 * pred["steady_round_ticks"]
    # un-alignable boundary aligns DOWN to a survivor span edge
    surv3 = parse_device_profiles([1.0, 1.25, 0.75])
    pred3 = predict_recovery(14, surv3, 2, boundary=11)
    assert pred3["boundary"] in [b for b, _ in pred3["spans"]] + [14]
    assert pred3["boundary"] <= 11
    # consistency with full_round_ticks at the predicted geometry
    F = pred3["frozen_stages"]
    want = full_round_ticks(pred3["spans"], 2, pred3["boundary"],
                            packed=F >= 2)
    assert pred3["recovery_round_ticks"] == want["round_ticks"]


# ---------------------------------------------------------------------------
# (b) straggler detection: EWMA re-fit + hysteresis, fires-at-most-once
# ---------------------------------------------------------------------------

SPEEDS = [1.0, 1.25, 0.5, 0.75]


def _stage_times(spans, speeds):
    return [sz / s for sz, s in zip(span_sizes(normalize_spans(spans)),
                                    speeds)]


def test_detector_fires_exactly_once_on_stable_skew():
    """Spans 4:4:3:3 over the true speeds 1.0:1.25:0.5:0.75 bottleneck at
    6.0 ticks vs 4.0 for the optimal 4:5:2:3 (ratio 1.5 >= 1.2): the
    detector fires after ``patience`` rounds, repartitions ONCE, and never
    proposes again on the equalized layout — the no-flapping pin."""
    det = StragglerDetector(parse_device_profiles(SPEEDS), 14,
                            threshold=1.2, patience=2)
    spans = normalize_spans([4, 4, 3, 3])
    props = []
    for _ in range(6):
        det.observe(spans, _stage_times(spans, SPEEDS))
        prop = det.propose(spans)
        props.append(prop)
        if prop is not None:
            spans = prop                               # apply the repartition
    fired = [p for p in props if p is not None]
    assert len(fired) == 1 and det.repartitions == 1
    assert span_sizes(fired[0]) == (4, 5, 2, 3)
    assert props[0] is None and props[1] is not None   # patience=2
    assert all(p is None for p in props[2:])           # equalized: no flap
    assert det.bottleneck(spans) == pytest.approx(4.0)


def test_detector_ewma_discovers_slowdown():
    """Seeded with unit profiles, a genuinely 4x-slower device 2 is
    discovered from measured stage times alone: the EWMA speed converges
    toward 0.25 and the proposal shrinks its span."""
    det = StragglerDetector(parse_device_profiles([1.0] * 4), 12, alpha=0.5,
                            threshold=1.2, patience=2)
    spans = normalize_spans([3, 3, 3, 3])
    true = [1.0, 1.0, 0.25, 1.0]
    prop = None
    for _ in range(8):
        det.observe(spans, _stage_times(spans, true))
        prop = det.propose(spans) or prop
    assert abs(det.speeds[2] - 0.25) < 0.05            # EWMA converged
    assert prop is not None
    assert span_sizes(prop)[2] < 3                     # straggler's span shrank
    # one transient slow round never triggers (patience + EWMA smoothing)
    det2 = StragglerDetector(parse_device_profiles([1.0] * 4), 12,
                             patience=2)
    det2.observe(spans, [3.0, 3.0, 12.0, 3.0])         # single GC-pause round
    assert det2.propose(spans) is None


def test_detector_membership_and_validation():
    det = StragglerDetector(parse_device_profiles(SPEEDS), 14)
    det.remove(2)
    assert [p.compute_speed for p in det.fleet] == [1.0, 1.25, 0.75]
    det.insert(2, DeviceProfile(0.5, float("inf")))
    assert [p.compute_speed for p in det.fleet] == SPEEDS
    with pytest.raises(ValueError, match="alpha"):
        StragglerDetector(det.fleet, 14, alpha=0.0)
    with pytest.raises(ValueError, match="threshold"):
        StragglerDetector(det.fleet, 14, threshold=0.9)
    with pytest.raises(ValueError, match="shape mismatch"):
        det.observe([4, 4, 3, 3], [1.0, 1.0, 1.0])


def test_device_profile_validation():
    """The bugfix satellite: NaN / non-positive speeds used to flow straight
    into Algorithm 1's span arithmetic (NaN poisons the binary search into
    returning degenerate spans); they now fail at construction."""
    for bad in (float("nan"), 0.0, -1.0, float("-inf")):
        with pytest.raises(ValueError, match="compute_speed"):
            DeviceProfile(compute_speed=bad, memory_mb=1.0)
        with pytest.raises(ValueError):
            parse_device_profiles([1.0, bad])
    with pytest.raises(ValueError, match="memory_mb"):
        DeviceProfile(compute_speed=1.0, memory_mb=float("nan"))
    with pytest.raises(ValueError, match="link_mbps"):
        DeviceProfile(compute_speed=1.0, memory_mb=1.0, link_mbps=0.0)
    assert DeviceProfile(2.0, 8.0).slowed(4.0).compute_speed == 0.5
    with pytest.raises(ValueError):
        DeviceProfile(2.0, 8.0).slowed(0.0)


# ---------------------------------------------------------------------------
# (c) + (d): executor/session differential — 4-device subprocess
# ---------------------------------------------------------------------------

PRELUDE = """
import json
import numpy as np
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.models import params as P
from repro.core import pipeline as pl
from repro.core.executor import RingExecutor
from repro.core.partition import parse_device_profiles
from repro.core.simulator import predict_recovery, spmd_tick_round

cfg = get_config("stablelm-3b").reduced(n_layers=14, repeats=14,
                                        d_model=64, d_ff=128, vocab_size=128)
S, M, mb, seq = 4, 2, 1, 16
SPEEDS = [1.0, 1.25, 0.5, 0.75]

def fresh_params():
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

mesh = compat.make_mesh((S,), ("stage",))

def batch(k=0):
    t = jax.random.randint(jax.random.key(10 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    l = jax.random.randint(jax.random.key(20 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    return t, l

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
host = lambda t: jax.tree.map(np.asarray, t)
"""


def test_shrink_differential_ticks_and_numerics():
    """The tentpole acceptance test, three crash scenarios on the 4-device
    mesh (uneven 4:5:2:3 layouts included, one case down-realigns the
    boundary, one lands on F=1 where packing is a no-op):

      * geometry — the executor's post-shrink spans/boundary equal
        ``predict_recovery``'s, the measured recovery (capture) and steady
        (cached) tick ledgers equal the simulator EXACTLY (integer equality);
      * numerics — post-shrink training is loss/param-equivalent (1e-5 /
        1e-3) to a FROM-SCRATCH S-1 executor built at the same spans with
        the same transplanted params + Adam moments + step counter: nothing
        was lost to the crash, no checkpoint was read;
      * the rebound activation cache re-captures: hit pattern
        [miss, miss, hit, hit] after the shrink on both rings.
    """
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
batches = [batch(0), batch(1)]
cases = [("4:5:2:3/kill2", [4, 5, 2, 3], 2),
         ("4:4:3:3/kill0", [4, 4, 3, 3], 0),
         ("4:5:2:3/kill3", [4, 5, 2, 3], 3)]
out = {}
for name, layout, dead in cases:
    profs = parse_device_profiles(SPEEDS)
    drv = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, spans=layout,
                       cache_capacity=2)
    with compat.set_mesh(mesh):
        for r in range(4):
            t, l = batches[r % 2]
            RingExecutor.materialize_metrics(drv.round(t, l, slot=r % 2))
    b_pre = drv.boundary_at(drv.step)
    surv = [p for i, p in enumerate(profs) if i != dead]
    drv.shrink(dead, profiles=surv)
    pred = predict_recovery(cfg.repeats, surv, M, b_pre, slots_per_epoch=2)
    b = drv.boundary_at(drv.step)

    # from-scratch S-1 twin: same spans, transplanted params+moments+step
    pc = host(drv.export_params())
    m_ad = host(pl.unstack_entry(drv.opt_state["m"]["adapter"], drv.spans))
    v_ad = host(pl.unstack_entry(drv.opt_state["v"]["adapter"], drv.spans))
    m_hd, v_hd = host(drv.opt_state["m"]["head"]), host(drv.opt_state["v"]["head"])
    count = int(drv.opt_state["count"])
    twin = RingExecutor(cfg, tc, drv.mesh, pc, S - 1, M,
                        spans=drv.spans, cache_capacity=2)
    twin.opt_state = {
        "m": {"adapter": pl.stack_entry(m_ad, twin.spans), "head": m_hd},
        "v": {"adapter": pl.stack_entry(v_ad, twin.spans), "head": v_hd},
        "count": jnp.asarray(count)}
    twin.step = drv.step

    rows = np.asarray([i for i in range(S) if i != dead])
    losses, hits = [], []
    with compat.set_mesh(drv.mesh):
        for r in range(4):
            t, l = batches[r % 2]
            ma = RingExecutor.materialize_metrics(
                drv.round(t[rows], l[rows], slot=r % 2))
            mt = RingExecutor.materialize_metrics(
                twin.round(t[rows], l[rows], slot=r % 2))
            losses.append((ma["loss"], mt["loss"]))
            hits.append((ma["cache_hit"], mt["cache_hit"]))

    led_r = drv.measured_tick_ledger(b, "capture")
    led_s = drv.measured_tick_ledger(b, "cached")
    S1 = S - 1
    sim_r = spmd_tick_round(drv.spans, M, b,
                            packed=led_r["frozen_stages"] >= 2)
    sim_s = spmd_tick_round(drv.spans, M, b, cached=True)
    out[name] = {
        "spans": [list(sp) for sp in drv.spans],
        "pred_spans": [list(sp) for sp in pred["spans"]],
        "b": b, "pred_b": pred["boundary"], "b_pre": b_pre,
        "losses": losses, "hits": hits,
        "param_err": maxerr(drv.export_params(), twin.export_params()),
        "frozen": led_r["frozen_stages"],
        "measured_recovery": led_r["phase_a_round_ticks"]
                             + S1 * 2 * led_r["bwd_ticks"],
        "measured_steady": led_s["phase_a_round_ticks"]
                           + S1 * 2 * led_s["bwd_ticks"],
        "pred_recovery": pred["recovery_round_ticks"],
        "pred_steady": pred["steady_round_ticks"],
        "sim_recovery_a": sim_r["phase_a_round_ticks"],
        "led_recovery_a": led_r["phase_a_round_ticks"],
        "sim_steady_a": sim_s["phase_a_round_ticks"],
        "led_steady_a": led_s["phase_a_round_ticks"],
    }
print(json.dumps(out))
"""
    res = _run_sub(code)
    saw_realign = saw_unpacked = False
    for name, rec in res.items():
        # geometry: executor == predict_recovery
        assert rec["spans"] == rec["pred_spans"], (name, rec)
        assert rec["b"] == rec["pred_b"], (name, rec)
        assert rec["b"] <= rec["b_pre"]                # aligns DOWN only
        saw_realign |= rec["b"] < rec["b_pre"]
        saw_unpacked |= rec["frozen"] < 2
        # tick differential: measured ledgers == simulator, exactly
        assert rec["led_recovery_a"] == rec["sim_recovery_a"], (name, rec)
        assert rec["led_steady_a"] == rec["sim_steady_a"] == 0, (name, rec)
        assert rec["measured_recovery"] == rec["pred_recovery"], (name, rec)
        assert rec["measured_steady"] == rec["pred_steady"], (name, rec)
        # numerics: post-shrink ring == from-scratch S-1 twin
        for a, t in rec["losses"]:
            assert math.isfinite(a) and abs(a - t) < 1e-5, (name, rec)
        assert rec["param_err"] < 1e-3, (name, rec)
        # checkpoint-free cache re-capture on both rings
        assert rec["hits"] == [[False, False], [False, False],
                               [True, True], [True, True]], (name, rec)
    assert saw_realign, "no case exercised boundary down-realignment"
    assert saw_unpacked, "no case exercised the F<2 unpacked recovery"


def test_chaos_session_kill_completes_and_resumes():
    """(d) end to end through RingSession: kill device 2 before round 3 of
    8 — training completes on the survivors with NO checkpoint restore,
    exactly one round is flagged ``layout_changed``, save -> restore across
    the shrink is bit-reproducible, and the same crash without ``elastic``
    raises instead of limping."""
    code = PRELUDE + """
import os, tempfile
from repro.api import RingSession
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
err = None
try:
    s0 = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                            chaos="1:crash:2", log=lambda *a: None)
    s0.run(3)
except RuntimeError as e:
    err = str(e)
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                          chaos="3:crash:2", elastic=True,
                          log=lambda *a: None)
hist = sess.run(8)
path = os.path.join(tempfile.mkdtemp(), "chaos_ck")
sess.save(path)
cont = [h["loss"] for h in sess.run(3)]
restored = RingSession.restore(path, cfg, tc, log=lambda *a: None)
again = [h["loss"] for h in restored.run(3)]
with open(path + ".json") as f:
    ex = json.load(f)["extra"]
print(json.dumps({
    "err": err,
    "marks": [bool(h.get("layout_changed")) for h in hist],
    "losses": [h["loss"] for h in hist],
    "survivors": hist[-1]["survivors"],
    "shrinks": sess.backend.shrinks,
    "spans": [list(sp) for sp in sess.backend.spans],
    "r_spans": [list(sp) for sp in restored.backend.spans],
    "r_survivors": list(restored.backend.survivors),
    "ck_survivors": ex.get("survivors"), "ck_stages": ex.get("n_stages"),
    "cont": cont, "again": again}))
"""
    res = _run_sub(code)
    assert res["err"] and "elastic" in res["err"], res["err"]
    assert res["marks"] == [False] * 3 + [True] + [False] * 4
    assert all(math.isfinite(l) for l in res["losses"])
    assert res["survivors"] == [0, 1, 3] and res["shrinks"] == 1
    # the checkpoint records the membership; restore replays it exactly
    assert res["ck_survivors"] == [0, 1, 3] and res["ck_stages"] == 4
    assert res["r_survivors"] == [0, 1, 3]
    assert res["r_spans"] == res["spans"]
    assert res["cont"] == res["again"], res            # bit-reproducible


def test_straggler_session_repartitions_once():
    """(b) through the live session: explicit 4:4:3:3 spans over the true
    speeds 1.0:1.25:0.5:0.75 — the detector's synthetic stage timings drive
    an EWMA re-fit that fires ONE hysteresis-gated repartition to the
    Algorithm-1 4:5:2:3 layout (round ``patience``), then stays quiet for
    the rest of the run."""
    code = PRELUDE + """
from repro.api import RingSession
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                          spans=[4, 4, 3, 3], device_profiles=SPEEDS,
                          elastic=True, log=lambda *a: None)
hist = sess.run(8)
print(json.dumps({
    "marks": [bool(h.get("layout_changed")) for h in hist],
    "losses": [h["loss"] for h in hist],
    "repartitions": sess.backend.repartitions,
    "shrinks": sess.backend.shrinks,
    "spans": [list(sp) for sp in sess.backend.spans],
    "stage_times": hist[-1]["stage_times"]}))
"""
    res = _run_sub(code)
    assert res["repartitions"] == 1 and res["shrinks"] == 0
    assert res["spans"] == [[0, 4], [4, 9], [9, 11], [11, 14]]
    assert res["marks"].count(True) == 1               # fired exactly once
    assert res["marks"][1]                             # at round patience=2
    assert all(math.isfinite(l) for l in res["losses"])
    # post-repartition the synthetic stage times are equalized (4.0 ticks)
    assert res["stage_times"] == pytest.approx([4.0] * 4)


def test_chaos_session_crash_then_rejoin_grows_back():
    """A crash at round 2 shrinks 4 -> 3; the same device rejoining at
    round 5 grows the ring back to 4 (``RingExecutor.grow``), training runs
    to completion throughout."""
    code = PRELUDE + """
from repro.api import RingSession
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                          chaos=["2:crash:1", "5:join:1"], elastic=True,
                          log=lambda *a: None)
hist = sess.run(8)
bad = None
try:
    RingSession.create(cfg, tc, backend="fused", n_stages=S,
                       chaos="1:join:7", elastic=True,
                       log=lambda *a: None).run(3)
except ValueError as e:
    bad = str(e)
print(json.dumps({
    "marks": [bool(h.get("layout_changed")) for h in hist],
    "losses": [h["loss"] for h in hist],
    "sizes": [len(h["survivors"]) for h in hist],
    "survivors": hist[-1]["survivors"],
    "spans": [list(sp) for sp in sess.backend.spans],
    "bad": bad}))
"""
    res = _run_sub(code)
    assert res["sizes"] == [4, 4, 3, 3, 3, 4, 4, 4]
    assert res["marks"] == [False, False, True, False, False,
                            True, False, False]
    assert res["survivors"] == [0, 1, 2, 3]
    assert len(res["spans"]) == 4
    assert all(math.isfinite(l) for l in res["losses"])
    # a device that never was in the fleet cannot join (the data source
    # owns exactly the original S rows)
    assert res["bad"] and "original fleet" in res["bad"], res["bad"]


def test_elastic_restore_remediation_repartitions_stale_layout():
    """The bugfix satellite: restoring a checkpoint whose span layout is
    stale for the CURRENT fleet used to leave the ring limping on the old
    spans (or force a fresh run).  With ``elastic=True`` +
    ``device_profiles``, restore loads the saved layout first (the moments
    are laid out per span) and then repartitions live to the fleet's
    Algorithm-1 layout, logging old -> new."""
    code = PRELUDE + """
import os, tempfile
from repro.api import RingSession
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                 initial_unfreeze_depth=3, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
path = os.path.join(tempfile.mkdtemp(), "stale_ck")
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                          log=lambda *a: None)
saved_spans = [list(sp) for sp in sess.backend.spans]
sess.run(2)
sess.save(path)
logs = []
res = RingSession.restore(path, cfg, tc, elastic=True,
                          device_profiles=SPEEDS, log=logs.append)
spans_after = [list(sp) for sp in res.backend.spans]
losses = [h["loss"] for h in res.run(2)]
# without elastic the stale layout is kept verbatim (back-compat)
res2 = RingSession.restore(path, cfg, tc, log=lambda *a: None)
print(json.dumps({
    "saved": saved_spans, "after": spans_after, "losses": losses,
    "kept": [list(sp) for sp in res2.backend.spans],
    "log": "\\n".join(str(l) for l in logs)}))
"""
    res = _run_sub(code)
    assert res["saved"] == [[0, 4], [4, 8], [8, 11], [11, 14]]
    assert res["after"] == [[0, 4], [4, 9], [9, 11], [11, 14]]  # 4:5:2:3
    assert res["kept"] == res["saved"]                 # non-elastic: verbatim
    assert "stale" in res["log"] and "repartition" in res["log"]
    assert all(math.isfinite(l) for l in res["losses"])
