"""Frozen-trunk activation cache (core/actcache.py + executor cached mode).

Pins the cache's contracts:

  (a) equivalence — with epoch-stable batch slots, the cached executor's
      losses and exported params match the cache-disabled fused executor
      exactly, INCLUDING across boundary drops (where the cache must
      invalidate and re-capture, not serve stale trunk activations),
  (b) accounting — hits/misses/invalidations/evictions/bypasses count what
      actually happened; slot=None and shape-mismatched batches fall back to
      the direct path,
  (c) compile counts — capture + cached are one executable each per boundary
      (the cached one has no Phase A at all: its HLO takes no tokens),
  (d) the ring-buffer host logic (LRU, invalidate, donated writes) in
      isolation on one device.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp

from repro.core.actcache import ActivationCache

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# (d) host-side ring-buffer logic, single device
# ---------------------------------------------------------------------------


def _entry(v, shape=(2, 3)):
    return jnp.full(shape, v, jnp.float32)


def test_cache_lru_eviction_and_reuse():
    c = ActivationCache(2)
    assert c.put(("s0", 3), _entry(1.0))
    assert c.put(("s1", 3), _entry(2.0))
    assert len(c) == 2
    # touch s0 so s1 becomes LRU, then insert s2 -> s1 evicted
    assert c.index_of(("s0", 3)) is not None
    assert c.put(("s2", 3), _entry(3.0))
    assert c.evictions == 1
    assert c.index_of(("s1", 3)) is None          # miss (evicted)
    i0, i2 = c.index_of(("s0", 3)), c.index_of(("s2", 3))
    assert i0 is not None and i2 is not None and i0 != i2
    assert float(c.buffer[i0][0, 0]) == 1.0       # survivor kept its bits
    assert float(c.buffer[i2][0, 0]) == 3.0       # evicted row was overwritten
    assert c.hits == 3 and c.misses == 1


def test_cache_put_overwrites_same_key():
    c = ActivationCache(2)
    c.put(("s0", 3), _entry(1.0))
    c.put(("s0", 3), _entry(9.0))
    assert len(c) == 1 and c.evictions == 0
    assert float(c.buffer[c.index_of(("s0", 3))][0, 0]) == 9.0


def test_cache_invalidate_keeps_buffer_counts_event():
    c = ActivationCache(2)
    c.put(("s0", 3), _entry(1.0))
    c.put(("s1", 3), _entry(2.0))
    assert c.invalidate() == 2
    assert c.invalidations == 1 and len(c) == 0
    assert c.invalidate() == 0                     # empty: no second event
    assert c.invalidations == 1
    # buffer survives (same shapes): re-capture reuses the allocation
    assert c.put(("s0", 2), _entry(5.0))
    assert float(c.buffer[c.index_of(("s0", 2))][0, 0]) == 5.0


def test_cache_shape_mismatch_bypasses():
    c = ActivationCache(2)
    c.put(("s0", 3), _entry(1.0))
    assert not c.compatible((4, 4))
    assert not c.put(("s1", 3), _entry(2.0, shape=(4, 4)))
    assert c.bypasses == 1 and len(c) == 1
    assert not c.compatible((2, 3), jnp.bfloat16)  # dtype checked when given
    assert c.compatible((2, 3), jnp.float32)


def test_cache_capacity_zero_disabled():
    c = ActivationCache(0)
    assert not c.compatible((2, 3))
    assert not c.put(("s0", 3), _entry(1.0))
    assert c.index_of(("s0", 3)) is None


def test_cache_free_rows_o1_and_consistent():
    """The free-row list replaces the O(capacity) first-free scan: rows stay
    unique, in range, and the free list + live rows always partition
    [0, capacity) — across fills, eviction, invalidation and refills."""
    c = ActivationCache(3)

    def check():
        live = list(c._rows.values())
        assert len(set(live)) == len(live)
        assert sorted(live + c._free) == list(range(3))

    for i in range(3):
        assert c.put((f"s{i}", 3), _entry(float(i)))
        check()
    assert c._free == []
    assert c.put(("s3", 3), _entry(3.0))          # evicts s0, reuses its row
    check()
    assert c.evictions == 1 and len(c) == 3
    c.invalidate()
    check()
    assert len(c._free) == 3
    for i in range(3):                            # refill reuses all rows
        assert c.put((f"t{i}", 2), _entry(10.0 + i))
        check()
    rows = {k: c.index_of(k) for k in (("t0", 2), ("t1", 2), ("t2", 2))}
    for k, r in rows.items():
        assert float(c.buffer[r][0, 0]) == 10.0 + int(k[0][1])


def test_cache_dtype_bf16_halves_bytes_roundtrip():
    c = ActivationCache(2, dtype="bf16")
    e = jnp.linspace(-3.0, 3.0, 6, dtype=jnp.float32).reshape(2, 3)
    assert c.put(("s0", 3), e)
    assert c.buffer.dtype == jnp.bfloat16
    assert c.scales is None
    from repro.core.actcache import dequantize
    back = dequantize(c.buffer[c.index_of(("s0", 3))], None, "bf16",
                      jnp.float32)
    assert float(jnp.abs(back - e).max()) < 0.05   # bf16 has ~3 digits
    # 2 bytes/elem vs f32's 4
    assert c.entry_bytes() == 2 * 6
    f = ActivationCache(2, dtype="f32")
    f.put(("s0", 3), e)
    assert f.entry_bytes() == 4 * 6


def test_cache_dtype_int8_scales_sidecar_roundtrip():
    c = ActivationCache(2, dtype="int8")
    e = jnp.linspace(-3.0, 3.0, 8, dtype=jnp.float32).reshape(2, 4)
    assert c.put(("s0", 3), e)
    assert c.buffer.dtype == jnp.int8
    assert c.scales is not None and c.scales.shape == (2, 2, 1)
    from repro.core.actcache import dequantize
    r = c.index_of(("s0", 3))
    back = dequantize(c.buffer[r], c.scales[r], "int8", jnp.float32)
    # symmetric per-row int8: error <= scale/2 = max|row| / 254
    row_max = jnp.max(jnp.abs(e), axis=-1, keepdims=True)
    assert bool((jnp.abs(back - e) <= row_max / 127.0).all())
    # 1 byte/elem + one f32 scale per 4-wide row
    assert c.entry_bytes() == 8 + 2 * 4
    st = c.stats()
    assert st["cache_dtype"] == "int8"
    assert st["cache_bytes_per_entry"] == 16
    assert st["cache_buffer_bytes"] == 32


def test_cache_source_dtype_still_guarded_under_compression():
    """compatible() checks the CAPTURED dtype, not the storage dtype — a
    bf16-compressed cache of f32 activations must still bypass bf16-source
    batches (they would silently dequantize to the wrong dtype)."""
    c = ActivationCache(2, dtype="bf16")
    c.put(("s0", 3), _entry(1.0))                  # f32 source
    assert c.compatible((2, 3), jnp.float32)
    assert not c.compatible((2, 3), jnp.bfloat16)
    assert not c.put(("s1", 3), _entry(2.0).astype(jnp.bfloat16))
    assert c.bypasses == 1


def test_cache_rejects_unknown_dtype():
    import pytest
    with pytest.raises(ValueError):
        ActivationCache(2, dtype="fp4")


def test_cache_span_layout_change_invalidates():
    """Entries are stage-local shards of a specific span layout: a
    repartition makes every held entry permanently wrong, so ``set_layout``
    must flush the whole cache (one invalidation event, like a boundary
    drop) while keeping the buffer allocation; the SAME layout is a no-op."""
    layout_a = ((0, 4), (4, 8), (8, 11), (11, 14))
    layout_b = ((0, 4), (4, 9), (9, 11), (11, 14))      # 4:5:2:3
    c = ActivationCache(2, layout=layout_a)
    assert c.layout == layout_a
    c.put(("s0", 11), _entry(1.0))
    c.put(("s1", 11), _entry(2.0))
    assert c.set_layout(layout_a) == 0                  # same layout: no-op
    assert len(c) == 2 and c.invalidations == 0
    assert c.set_layout(layout_b) == 2                  # repartition: flush
    assert c.layout == layout_b
    assert len(c) == 0 and c.invalidations == 1
    assert c.index_of(("s0", 11)) is None
    # buffer survives (same entry shapes): re-capture reuses the allocation
    assert c.put(("s0", 11), _entry(5.0))
    assert float(c.buffer[c.index_of(("s0", 11))][0, 0]) == 5.0
    # an empty cache still tracks the layout without a spurious event
    d = ActivationCache(2, layout=layout_a)
    assert d.set_layout(layout_b) == 0
    assert d.invalidations == 0 and d.layout == layout_b


def test_cache_shape_mismatch_bypasses_at_nonuniform_boundary():
    """Shape-mismatch bypass is orthogonal to the span layout: a ragged
    layout's cache still refuses (and counts) entries whose shapes don't fit
    the allocated buffer, at span-aligned (non-lps-multiple) boundaries."""
    c = ActivationCache(2, layout=((0, 4), (4, 9), (9, 11), (11, 14)))
    assert c.put(("s0", 9), _entry(1.0))                # boundary 9: 2 stages
    assert not c.compatible((4, 4))
    assert not c.put(("s1", 9), _entry(2.0, shape=(4, 4)))
    assert c.bypasses == 1 and len(c) == 1
    assert c.index_of(("s0", 9)) is not None            # survivor intact
    # a boundary key from another span edge shares the buffer fine
    assert c.put(("s0", 11), _entry(3.0))


# ---------------------------------------------------------------------------
# (a)+(b)+(c): cached executor vs cache-disabled fused executor, 4 devices
# ---------------------------------------------------------------------------

PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.models import params as P
from repro.core.executor import RingExecutor

cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
S, M, mb, seq = 4, 3, 1, 32

def fresh_params():
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

mesh = compat.make_mesh((4,), ("stage",))

def slot_batch(k, seq_=seq):
    t = jax.random.randint(jax.random.key(10 + k), (S, M, mb, seq_), 0,
                           cfg.vocab_size)
    l = jax.random.randint(jax.random.key(20 + k), (S, M, mb, seq_), 0,
                           cfg.vocab_size)
    return t, l

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_cached_matches_uncached_across_boundary_drop():
    """(a)+(c): 2 slots x 6 rounds per driver, boundary walking 3 -> 2 -> 1
    (interval = 4 rounds' worth of steps => 2 epochs per boundary: capture,
    capture, hit, hit).  Losses and final params must match the cache-disabled
    executor, the cache must invalidate on each drop, and each boundary must
    compile exactly one capture + one cached executable."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=4 * S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
batches = [slot_batch(0), slot_batch(1)]
out = {"plain_loss": [], "cached_loss": [], "hit": [], "b": []}
with compat.set_mesh(mesh):
    plain = RingExecutor(cfg, tc, mesh, fresh_params(), S, M)
    drv = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, cache_capacity=2)
    for r in range(12):
        slot = r % 2
        t, l = batches[slot]
        mp = RingExecutor.materialize_metrics(plain.round(t, l))
        mc = RingExecutor.materialize_metrics(drv.round(t, l, slot=slot))
        out["plain_loss"].append(mp["loss"])
        out["cached_loss"].append(mc["loss"])
        out["hit"].append(mc["cache_hit"])
        out["b"].append(mc["boundary"])
        assert mp["boundary"] == mc["boundary"]
    out["param_err"] = maxerr(plain.export_params(), drv.export_params())
    out["stats"] = drv.cache.stats()
    out["compiles"] = drv.compile_counts()
    out["plain_compiles"] = plain.compile_counts()
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["b"] == [3] * 4 + [2] * 4 + [1] * 4
    # capture, capture, hit, hit at every boundary
    assert res["hit"] == [False, False, True, True] * 3
    # (a) cached == uncached, including the rounds right after each drop
    for pl, cl in zip(res["plain_loss"], res["cached_loss"]):
        assert abs(pl - cl) < 1e-5, (res["plain_loss"], res["cached_loss"])
    assert res["param_err"] < 1e-3
    st = res["stats"]
    assert st["cache_hits"] == 6 and st["cache_misses"] == 6
    assert st["cache_invalidations"] == 2          # drops 3->2 and 2->1
    assert st["cache_evictions"] == 0 and st["cache_bypasses"] == 0
    # (c) one capture + one cached executable per boundary, nothing else
    assert res["compiles"] == {f"{b}/{m}": 1 for b in (3, 2, 1)
                               for m in ("capture", "cached")}
    assert res["plain_compiles"] == {f"{b}/direct": 1 for b in (3, 2, 1)}


def test_cache_bypass_fallbacks():
    """(b): slot=None routes to the direct executable (no cache traffic);
    a batch whose shapes don't fit the allocated buffer bypasses; capacity-1
    thrashing evicts instead of hitting — and numerics survive all of it."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
b0, b1 = slot_batch(0), slot_batch(1)
short = slot_batch(2, seq_=16)
out = {}
with compat.set_mesh(mesh):
    drv = RingExecutor(cfg, tc, mesh, fresh_params(), S, M, cache_capacity=1)
    drv.round(*b0, slot=None)                 # streaming round: direct path
    out["after_none"] = drv.cache.stats()
    drv.round(*b0, slot=0)                    # capture slot 0
    drv.round(*b1, slot=1)                    # capacity 1 -> evicts slot 0
    drv.round(*b0, slot=0)                    # miss again (was evicted)
    out["after_thrash"] = drv.cache.stats()
    drv.round(*short, slot=3)                 # doesn't fit allocated buffer
    out["after_short"] = drv.cache.stats()
    drv.round(*b0, slot=0)                    # still works, still a hit
    out["final"] = drv.cache.stats()
    out["compiles"] = drv.compile_counts()
print(json.dumps(out))
"""
    res = _run_sub(code)
    a = res["after_none"]
    assert a["cache_hits"] == 0 and a["cache_misses"] == 0, a
    t = res["after_thrash"]
    assert t["cache_misses"] == 3 and t["cache_evictions"] == 2
    s = res["after_short"]
    assert s["cache_bypasses"] == 1
    assert s["cache_misses"] == 3                  # bypass is not a miss
    f = res["final"]
    assert f["cache_hits"] == 1
    comp = res["compiles"]
    # direct compiled twice: once for slot=None, once for the short batch's
    # distinct shapes; capture once; cached once (first actual hit)
    assert comp["3/capture"] == 1 and comp["3/cached"] == 1
    assert comp["3/direct"] == 2, comp
