"""RingSession facade (repro/api): the pluggable-API contracts.

Pins:

  (a) session-vs-oracle equivalence — every backend reproduces the driver it
      wraps: PjitBackend matches the staged-recompile loop the seed's
      train_pjit ran (exact same ops, tight tolerance); Reference/Fused
      backends match RingTrainer/RingExecutor driven directly (and track each
      other within the cross-driver tolerances test_executor.py pins); the
      Cached backend matches the cache-disabled fused session across a
      boundary drop within test_actcache.py's tolerances,
  (b) policy protocol — every UnfreezePolicy (incl. LossPlateauPolicy under
      adversarial loss curves: rising, oscillating, NaN/inf) emits a
      monotone depth/boundary sequence; the session's runtime check rejects a
      policy that violates the contract,
  (c) checkpointing — ``checkpoint.save(..., opt_state=...)`` round-trips the
      Adam moments even with adapters_only=True, and a restored session
      continues with IDENTICAL losses for 5 steps (pjit inline; ring in a
      4-device subprocess).
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (ExplicitPolicy, IntervalPolicy, LossPlateauPolicy,
                       RingSession, resolve_policy)
from repro.configs import TrainConfig, get_config
from repro.core.unfreeze import depth_to_boundary

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# (b) policy protocol: monotone boundary under ANY loss sequence
# ---------------------------------------------------------------------------

N_BLOCKS = 8


def _adversarial_curves():
    rng = np.random.default_rng(0)
    curves = {
        "decreasing": [5.0 / (1 + 0.1 * i) for i in range(120)],
        "increasing": [1.0 + 0.1 * i for i in range(120)],
        "oscillating": [3.0 + 2.0 * math.sin(i) for i in range(120)],
        "constant": [2.0] * 120,
        "cliff_then_flat": [5.0] * 10 + [0.5] * 110,
        "nan_inf_mix": [float("nan"), float("inf"), 1.0, float("-inf"),
                        2.0, float("nan")] * 20,
    }
    for s in range(3):
        curves[f"random_{s}"] = list(rng.normal(3.0, 2.0, size=120))
    return curves


def _policies():
    return {
        "interval": IntervalPolicy(initial_depth=1, interval=7),
        "explicit": ExplicitPolicy((1, 2, 2, 5, 8), interval=9),
        "plateau_p1": LossPlateauPolicy(initial_depth=1, patience=1,
                                        min_rel_improve=1e-2),
        "plateau_p3": LossPlateauPolicy(initial_depth=2, patience=3,
                                        min_rel_improve=1e-3, smoothing=0.9),
    }


@pytest.mark.parametrize("curve_name", sorted(_adversarial_curves()))
@pytest.mark.parametrize("policy_name", sorted(_policies()))
def test_policy_monotone_boundary_property(policy_name, curve_name):
    """Depth never shrinks / boundary never rises, for every policy under
    every loss curve — the activation cache's invalidation contract."""
    cfg = get_config("stablelm-3b").reduced(n_layers=N_BLOCKS,
                                            repeats=N_BLOCKS)
    policy = _policies()[policy_name]
    losses = _adversarial_curves()[curve_name]
    prev_depth, prev_boundary = 0, cfg.repeats
    for step, loss in enumerate(losses):
        d = policy.depth_at(step, N_BLOCKS)
        b = depth_to_boundary(cfg, d)
        assert 1 <= d <= N_BLOCKS, (step, d)
        assert d >= prev_depth, f"depth shrank {prev_depth}->{d} at {step}"
        assert b <= prev_boundary, f"boundary rose {prev_boundary}->{b}"
        prev_depth, prev_boundary = d, b
        policy.observe(step, loss)


def test_plateau_policy_unfreezes_on_plateau_only():
    """Improving loss holds depth; a plateau bumps it by exactly one."""
    p = LossPlateauPolicy(initial_depth=1, patience=2, min_rel_improve=1e-2,
                          smoothing=0.0)
    for step, loss in enumerate([5.0, 4.0, 3.0, 2.0]):  # steady improvement
        p.observe(step, loss)
    assert p.depth_at(4, N_BLOCKS) == 1
    for step in range(4, 8):                            # flatline: plateau
        p.observe(step, 2.0)
    assert p.depth_at(8, N_BLOCKS) > 1


def test_explicit_policy_rejects_non_monotone():
    with pytest.raises(ValueError, match="non-monotone"):
        ExplicitPolicy((1, 3, 2))


def test_resolve_policy_names():
    tc = TrainConfig(unfreeze_interval=13)
    p = resolve_policy(None, tc)
    assert isinstance(p, IntervalPolicy) and p._sched.interval == 13
    assert isinstance(resolve_policy("plateau", tc), LossPlateauPolicy)
    with pytest.raises(ValueError, match="unknown policy"):
        resolve_policy("nope", tc)


def test_session_rejects_rising_boundary_at_runtime():
    """Defense-in-depth: a policy that breaks the contract mid-run (not at
    construction) is caught by the session's per-step check."""
    class Malicious:
        wants_loss = False

        def depth_at(self, step, n_blocks):
            return 3 if step < 2 else 1          # depth shrinks: boundary rises

        def observe(self, step, loss):
            pass

        def state(self):
            return {}

        def load_state(self, state):
            pass

    cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                            d_model=64, d_ff=128,
                                            vocab_size=128)
    tc = TrainConfig(batch_size=2, seq_len=16)
    sess = RingSession.create(cfg, tc, backend="pjit", policy=Malicious())
    sess.step()
    sess.step()
    with pytest.raises(RuntimeError, match="monotone"):
        sess.step()


# ---------------------------------------------------------------------------
# (c) checkpoint: opt-state round-trip + identical-loss resume (pjit, inline)
# ---------------------------------------------------------------------------


def test_checkpoint_opt_state_roundtrip(tmp_path):
    """adapters_only=True used to DROP the optimizer state entirely; now it
    rides along in the opt:: namespace and restores bit-exactly."""
    import jax
    from repro.checkpoint import checkpoint as ckpt
    from repro.core import training
    from repro.models import params as prm
    from repro.optim import adamw

    cfg = get_config("stablelm-3b").reduced(n_layers=2, repeats=2,
                                            d_model=64, d_ff=128,
                                            vocab_size=128)
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    opt = adamw.init(training.full_trainable(params))
    # make the moments non-trivial so the round-trip is meaningful
    opt = jax.tree.map(lambda x: x + 0.25 if x.dtype == np.float32 else x, opt)
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, step=3, opt_state=opt, adapters_only=True)
    back = ckpt.restore_opt(path, jax.tree.map(np.zeros_like, opt))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a checkpoint without opt state refuses to pretend it can resume
    ckpt.save(os.path.join(tmp_path, "noopt"), params, adapters_only=True)
    with pytest.raises(ValueError, match="no optimizer state"):
        ckpt.restore_opt(os.path.join(tmp_path, "noopt"), opt)


def _tiny_pjit_setup():
    cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                            d_model=128, d_ff=256)
    tc = TrainConfig(learning_rate=1e-3, batch_size=2, seq_len=32,
                     unfreeze_interval=3)
    return cfg, tc


def test_pjit_session_resumes_with_identical_losses(tmp_path):
    """Save mid-run; the restored session's next 5 losses are IDENTICAL to
    the uninterrupted run's (params + Adam moments + policy step + data
    cursor all round-trip)."""
    cfg, tc = _tiny_pjit_setup()
    path = os.path.join(tmp_path, "ck")
    sess = RingSession.create(cfg, tc, backend="pjit")
    sess.run(4)
    sess.save(path)
    cont = [h["loss"] for h in sess.run(5)]
    restored = RingSession.restore(path, cfg, tc)
    again = [h["loss"] for h in restored.run(5)]
    assert cont == again, (cont, again)
    assert restored.step_count == sess.step_count


def test_restore_policy_mismatch_raises(tmp_path):
    cfg, tc = _tiny_pjit_setup()
    path = os.path.join(tmp_path, "ck")
    sess = RingSession.create(cfg, tc, backend="pjit")
    sess.run(1)
    sess.save(path)
    with pytest.raises(ValueError, match="policy"):
        RingSession.restore(path, cfg, tc, policy=LossPlateauPolicy())


# ---------------------------------------------------------------------------
# (a) session vs oracle: pjit (inline, 1 device)
# ---------------------------------------------------------------------------


def test_pjit_session_matches_staged_recompile_oracle():
    """The session's pjit backend reruns EXACTLY the loop the seed's
    train_pjit hand-wired: same Batcher draws, same boundary segments, same
    jitted+donated step fns — losses and params must agree to float noise."""
    import jax
    import jax.numpy as jnp
    from repro.core import training
    from repro.core.unfreeze import UnfreezeSchedule, boundary_schedule
    from repro.data.pipeline import Batcher, make_client_datasets, merged
    from repro.models import params as prm
    from repro.optim import adamw

    cfg, tc = _tiny_pjit_setup()
    steps = 8

    # --- oracle: the pre-session train_pjit loop, verbatim ---
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(tc.seed),
                             cfg.dtype)
    opt_state = adamw.init(training.full_trainable(params))
    ds = merged(make_client_datasets(4, vocab=cfg.vocab_size, n_per_client=256,
                                     seq=tc.seq_len, seed=tc.seed, kind="lm"))
    batcher = Batcher(ds, tc.batch_size, seed=tc.seed)
    segs = boundary_schedule(cfg, UnfreezeSchedule.from_train_config(tc), steps)
    oracle_losses, step_fns = [], {}
    for (s0, s1, boundary) in segs:
        if boundary not in step_fns:
            step_fns[boundary] = jax.jit(
                training.make_train_step(cfg, tc, boundary),
                donate_argnums=(0, 1))
        for _ in range(s0, s1):
            params, opt_state, metrics = step_fns[boundary](
                params, opt_state, batcher.next())
            oracle_losses.append(float(metrics["loss"]))

    # --- session ---
    sess = RingSession.create(cfg, tc, backend="pjit")
    hist = sess.run(steps)
    sess_losses = [h["loss"] for h in hist]

    for ol, sl in zip(oracle_losses, sess_losses):
        assert abs(ol - sl) < 1e-6, (oracle_losses, sess_losses)
    f32 = lambda x: np.asarray(x, np.float32)
    err = max(float(np.abs(f32(a) - f32(b)).max()) for a, b in
              zip(jax.tree.leaves(params),
                  jax.tree.leaves(sess.backend.export_params())))
    assert err < 1e-5, err
    assert hist[-1]["compile_count"] == len(step_fns)


# ---------------------------------------------------------------------------
# (a) session vs oracle: ring backends (4-device subprocess)
# ---------------------------------------------------------------------------

PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.api import RingSession
from repro.configs import TrainConfig, get_config
from repro.models import params as P

cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
S, M, mb, seq = 4, 3, 1, 32

def fresh_params():
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

def slot_batch(k, seq_=seq):
    t = jax.random.randint(jax.random.key(10 + k), (S, M, mb, seq_), 0,
                           cfg.vocab_size)
    l = jax.random.randint(jax.random.key(20 + k), (S, M, mb, seq_), 0,
                           cfg.vocab_size)
    return t, l

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_ring_backends_match_direct_drivers():
    """ReferenceBackend == RingTrainer and FusedBackend == RingExecutor when
    driven on identical batches across a boundary bump; the two backends
    track each other within the cross-driver tolerances test_executor pins."""
    code = PRELUDE + """
from repro.core.ring import RingTrainer
from repro.core.executor import RingExecutor

mesh = compat.make_mesh((4,), ("stage",))
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
tokens, labels = slot_batch(0)
out = {k: [] for k in ("drv_ref", "ses_ref", "drv_fused", "ses_fused", "b")}
with compat.set_mesh(mesh):
    drv_ref = RingTrainer(cfg, tc, mesh, fresh_params(), S, M)
    drv_fused = RingExecutor(cfg, tc, mesh, fresh_params(), S, M)
    ses_ref = RingSession.create(cfg, tc, backend="reference", n_stages=S,
                                 params=fresh_params())
    ses_fused = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                                   params=fresh_params())
    for r in range(3):
        mr = drv_ref.round(tokens, labels)
        mf = RingExecutor.materialize_metrics(drv_fused.round(tokens, labels))
        sr = ses_ref.step((tokens, labels)).materialize()
        sf = ses_fused.step((tokens, labels)).materialize()
        out["drv_ref"].append(mr["loss"]); out["ses_ref"].append(sr.loss)
        out["drv_fused"].append(mf["loss"]); out["ses_fused"].append(sf.loss)
        assert mr["boundary"] == sr.boundary == mf["boundary"] == sf.boundary
        out["b"].append(sr.boundary)
    out["ref_param_err"] = maxerr(drv_ref.export_params(),
                                  ses_ref.backend.export_params())
    out["fused_param_err"] = maxerr(drv_fused.export_params(),
                                    ses_fused.backend.export_params())
    out["cross_param_err"] = maxerr(ses_ref.backend.export_params(),
                                    ses_fused.backend.export_params())
    out["ses_fused_compiles"] = ses_fused.backend.compile_count
    out["ses_ref_compiles"] = ses_ref.backend.compile_count
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["b"] == [3, 2, 1]
    # same driver under the session facade: agreement to float noise
    for dr, sr in zip(res["drv_ref"], res["ses_ref"]):
        assert abs(dr - sr) < 1e-6, (res["drv_ref"], res["ses_ref"])
    for df, sf in zip(res["drv_fused"], res["ses_fused"]):
        assert abs(df - sf) < 1e-6, (res["drv_fused"], res["ses_fused"])
    assert res["ref_param_err"] < 1e-5
    assert res["fused_param_err"] < 1e-5
    # cross-driver: the tolerances test_executor.py pins (bf16 params,
    # different reduce orders)
    for sr, sf in zip(res["ses_ref"], res["ses_fused"]):
        assert abs(sr - sf) < 2e-2
    assert res["cross_param_err"] < 5e-2
    # compile counts surface through the facade: 1 per boundary fused,
    # S per boundary reference
    assert res["ses_fused_compiles"] == 3
    assert res["ses_ref_compiles"] == 3 * 4


def test_cached_session_matches_fused_across_boundary_drop():
    """CachedBackend == FusedBackend on identical slotted data, INCLUDING
    across boundary drops (invalidate + re-capture, never stale activations)
    — test_actcache.py's tolerances, through the facade."""
    code = PRELUDE + """
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=4 * S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
batches = [slot_batch(0), slot_batch(1)]
out = {"plain": [], "cached": [], "hit": [], "b": []}
plain = RingSession.create(cfg, tc, backend="fused", n_stages=S,
                           params=fresh_params())
drv = RingSession.create(cfg, tc, backend="cached", n_stages=S,
                         slots_per_epoch=2, params=fresh_params())
for r in range(12):
    slot = r % 2
    t, l = batches[slot]
    mp = plain.step((slot, t, l)).materialize()
    mc = drv.step((slot, t, l)).materialize()
    out["plain"].append(mp.loss)
    out["cached"].append(mc.loss)
    out["hit"].append(mc.cache_hit)
    out["b"].append(mc.boundary)
    assert mp.boundary == mc.boundary
out["param_err"] = maxerr(plain.export_params(), drv.export_params())
out["stats"] = drv.backend.driver.cache.stats()
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["b"] == [3] * 4 + [2] * 4 + [1] * 4
    assert res["hit"] == [False, False, True, True] * 3
    for pl, cl in zip(res["plain"], res["cached"]):
        assert abs(pl - cl) < 1e-5, (res["plain"], res["cached"])
    assert res["param_err"] < 1e-3
    st = res["stats"]
    assert st["cache_hits"] == 6 and st["cache_misses"] == 6
    assert st["cache_invalidations"] == 2


def test_ring_session_resumes_with_identical_losses(tmp_path):
    """The --save/--resume bugfix, pinned end-to-end: a fused ring session
    saved mid-run and restored continues with IDENTICAL losses (params +
    stage-stacked Adam moments + policy step + data cursor round-trip)."""
    code = PRELUDE + f"""
import os
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=2 * S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
path = os.path.join({str(tmp_path)!r}, "ring_ck")
sess = RingSession.create(cfg, tc, backend="fused", n_stages=S)
sess.run(2)
sess.save(path)
cont = [h["loss"] for h in sess.run(5)]
restored = RingSession.restore(path, cfg, tc)
again = [h["loss"] for h in restored.run(5)]
bad_restore = None
try:
    RingSession.restore(path, cfg, tc, backend="pjit")
except ValueError as e:
    bad_restore = str(e)
print(json.dumps({{"cont": cont, "again": again, "bad": bad_restore,
                   "step": restored.step_count}}))
"""
    res = _run_sub(code)
    assert res["cont"] == res["again"], (res["cont"], res["again"])
    assert res["step"] == 7 * 4
    assert res["bad"] and "format" in res["bad"]
