"""Multi-tenant personalization: joint T-tenant sessions vs solo oracles.

Pins the tentpole contracts of the tenant-packed ring (core/pipeline.py
``ring_phase_a_packed(n_tenants=T)`` + core/executor.py ``tenants=T`` +
api/tenants.py):

  (a) differential oracle — a joint T=4 cached session equals 4 independent
      single-tenant sessions (each fed tenant k's stream via
      ``RingDataSource(..., tenant=k)``) at the f32 pins (1e-5 losses /
      1e-3 adapters), across a boundary drop.  The tenant conveyor chains
      tenants on the TIME axis (T*S*M + F - 1 ticks, solo per-tick shapes),
      so every microbatch runs the same op sequence as its solo run and the
      match is in fact bit-exact,
  (b) partitioned cache — reloading ONE tenant's adapters invalidates only
      that tenant's (tenant, slot, boundary) entries: the neighbors' hit
      counters keep climbing uninterrupted, and (since the reload writes
      back the same values) the whole run stays loss-identical to an
      untouched control,
  (c) isolation — tenant i's losses are a function of tenant i's data only:
      perturbing tenant j's stream leaves every other tenant's per-round
      loss bit-unchanged under the shared frozen trunk,
  (d) metrics flush — lazy device RoundMetrics held across a
      ``session.repartition()`` are host-synced BEFORE the restack donates
      the buffers they point at (satellite fix; without the flush the read
      returns garbage or dies).
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# f32 model: the differential pins compare adapter trees after Adam steps,
# and bf16 rounding would swamp the 1e-3 adapter pin.
PRELUDE = """
import dataclasses, json
import jax, jax.numpy as jnp
from repro.configs import TrainConfig, get_config
from repro.api import AdapterStore, RingSession
from repro.api.data import RingDataSource

S, M, mb, seq = 4, 2, 2, 8
cfg = dataclasses.replace(
    get_config("qwen2.5-3b").reduced(n_layers=8, repeats=8), dtype="float32")

def make_tc(interval):
    return TrainConfig(seed=0, learning_rate=1e-3, warmup_steps=1,
                       unfreeze_interval=interval, initial_unfreeze_depth=4,
                       n_stages=S, n_microbatches=M, batch_size=mb,
                       seq_len=seq)

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_joint_matches_solos_across_boundary_drop():
    """(a): T=4 joint cached session vs 4 independent solo sessions, with
    the unfreeze boundary dropping mid-run (interval = 2 rounds' steps) —
    per-round per-tenant losses at 1e-5, final adapter bundles at 1e-3."""
    code = PRELUDE + """
T, rounds = 4, 6
tc = make_tc(2 * S)                       # one drop every 2 rounds
joint = RingSession.create(cfg, tc, backend="cached", tenants=T,
                           slots_per_epoch=2)
hist = joint.run(rounds, log_every=1)
out = {"joint": [[h["tenant_losses"][t] for h in hist] for t in range(T)],
       "bounds": [h["boundary"] for h in hist],
       "solo": [], "ad_err": [], "solo_bounds": None}
for t in range(T):
    solo = RingSession.create(cfg, tc, backend="cached", slots_per_epoch=2)
    solo.data = RingDataSource(cfg, tc, S, slots_per_epoch=2, tenant=t)
    h = solo.run(rounds, log_every=1)
    out["solo"].append([r["loss"] for r in h])
    out["solo_bounds"] = [r["boundary"] for r in h]
    out["ad_err"].append(maxerr(joint.export_adapters(tenant=t),
                                solo.export_adapters()))
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert len(set(res["bounds"])) > 1, res["bounds"]       # drop happened
    assert res["solo_bounds"] == res["bounds"]              # same schedule
    for t in range(4):
        for jl, sl in zip(res["joint"][t], res["solo"][t]):
            assert abs(jl - sl) < 1e-5, (t, res)
        assert res["ad_err"][t] < 1e-3, (t, res)
    # the tenants actually train on distinct streams (losses differ)
    assert len({tuple(r) for r in res["joint"]}) == 4


def test_tenant_invalidation_leaves_neighbor_hit_rates(tmp_path):
    """(b): after a warm cache (all tenants hitting), a round trip through
    an AdapterStore into tenant 1 frees ONLY tenant 1's entries — the next
    epoch re-captures tenant 1 while tenants 0/2 keep hitting — and the run
    stays loss-identical to a control that never reloaded."""
    code = (PRELUDE
            + f"store_dir = {json.dumps(str(tmp_path / 'adstore'))}\n" + """
T, tc = 3, make_tc(10**6)
sess = RingSession.create(cfg, tc, backend="cached", tenants=T,
                          slots_per_epoch=2)
ctrl = RingSession.create(cfg, tc, backend="cached", tenants=T,
                          slots_per_epoch=2)
h1 = sess.run(4, log_every=1)             # capture x2, hit x2
warm = (h1[-1]["tenant_cache_hits"], h1[-1]["tenant_cache_misses"])
store = AdapterStore(store_dir)
sess.tenants[1].save_to(store, "t1")
sess.tenants[1].load_from(store, "t1")    # same values; frees tenant-1 rows
inval = sess.backend.driver.cache.invalidations
h2 = sess.run(4, log_every=1)             # t1 re-captures, 0/2 keep hitting
cold = (h2[-1]["tenant_cache_hits"], h2[-1]["tenant_cache_misses"])
hc = ctrl.run(8, log_every=1)
out = {"warm_hits": warm[0], "warm_misses": warm[1],
       "cold_hits": cold[0], "cold_misses": cold[1],
       "inval": inval, "has_opt": store.has_opt("t1"),
       "loss": [h["loss"] for h in h1 + h2],
       "ctrl": [h["loss"] for h in hc]}
print(json.dumps(out))
""")
    res = _run_sub(code)
    # warm: 2 capture rounds (miss each tenant) then 2 all-hit rounds
    assert res["warm_hits"] == [2, 2, 2] and res["warm_misses"] == [2, 2, 2]
    assert res["inval"] == 1 and res["has_opt"]
    # post-reload epoch: tenant 1 misses both slots, neighbors hit through —
    # then the final epoch is all-hit again
    assert res["cold_hits"] == [6, 4, 6], res
    assert res["cold_misses"] == [2, 4, 2], res
    # reloading identical values must not perturb training
    for a, b in zip(res["loss"], res["ctrl"]):
        assert a == b, res


def test_tenant_isolation():
    """(c): perturb tenant 2's data stream (different seed) — tenants 0/1
    see bit-identical per-round losses, tenant 2 diverges."""
    code = PRELUDE + """
T, tc = 3, make_tc(10**6)
a = RingSession.create(cfg, tc, backend="fused", tenants=T)
b = RingSession.create(cfg, tc, backend="fused", tenants=T)
tc2 = dataclasses.replace(tc, seed=1234)
b.data.rbs[2] = RingDataSource(cfg, tc2, S, tenants=T).rbs[2]
ha = a.run(4, log_every=1)
hb = b.run(4, log_every=1)
out = {"a": [[h["tenant_losses"][t] for h in ha] for t in range(T)],
       "b": [[h["tenant_losses"][t] for h in hb] for t in range(T)]}
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["a"][0] == res["b"][0]          # bit-equal: untouched tenants
    assert res["a"][1] == res["b"][1]
    assert res["a"][2] != res["b"][2]          # the perturbed one moved


def test_metrics_flushed_before_repartition():
    """(d): a lazy RoundMetrics held across ``session.repartition`` is
    host-synced by the flush (materialized, finite, and equal to the value
    an immediately-materialized control read), and training continues on
    the new layout with unchanged numerics."""
    code = PRELUDE + """
T, tc = 2, make_tc(10**6)
sess = RingSession.create(cfg, tc, backend="fused", tenants=T)
ctrl = RingSession.create(cfg, tc, backend="fused", tenants=T)
m = sess.step()                           # lazy: loss is a device array
mc = ctrl.step().materialize()
was_lazy = not m.materialized
# 2:2:2:2 -> 3:1:2:2 keeps a span edge at repeat 4, so the span-aligned
# unfreeze boundary (initial depth 4) is unchanged and numerics must hold
sess.repartition([3, 1, 2, 2])            # donates the old stacks
out = {"was_lazy": was_lazy, "flushed": m.materialized,
       "loss": m.loss, "ctrl_loss": mc.loss,
       "tl": m.extras["tenant_losses"],
       "ctrl_tl": mc.extras["tenant_losses"],
       "next_loss": sess.step().materialize().loss,
       "ctrl_next": ctrl.step().materialize().loss,
       "spans": [list(sp) for sp in sess.backend.spans]}
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["was_lazy"] and res["flushed"]
    assert res["loss"] == res["ctrl_loss"]      # flushed pre-donation bits
    assert res["tl"] == res["ctrl_tl"]
    assert res["spans"] == [[0, 3], [3, 4], [4, 6], [6, 8]]
    # repartition preserves numerics (restack is exact)
    assert abs(res["next_loss"] - res["ctrl_next"]) < 1e-5


def test_tenant_isolation_survives_elastic_shrink():
    """Multi-tenant churn: a chaos crash mid-run shrinks the joint T=3 ring
    4 -> 3 stages.  The shrink restacks ALL tenants' adapters + moments
    exactly, so (c)'s bit-identity pin must survive it: perturbing tenant
    2's stream still leaves tenants 0/1's per-round losses bit-unchanged
    through the shrink round and after, and the partitioned cache
    re-captures every live tenant's rows (miss x2, then hits again)."""
    code = PRELUDE + """
T, tc = 3, make_tc(10**6)
mk = lambda: RingSession.create(cfg, tc, backend="cached", tenants=T,
                                slots_per_epoch=2, chaos="2:crash:3",
                                elastic=True, log=lambda *a: None)
a, b = mk(), mk()
tc2 = dataclasses.replace(tc, seed=1234)
b.data.rbs[2] = RingDataSource(cfg, tc2, S, tenants=T,
                               slots_per_epoch=2).rbs[2]
ha = a.run(6, log_every=1)
hb = b.run(6, log_every=1)
out = {"a": [[h["tenant_losses"][t] for h in ha] for t in range(T)],
       "b": [[h["tenant_losses"][t] for h in hb] for t in range(T)],
       "marks": [bool(h.get("layout_changed")) for h in ha],
       "hits": [h["cache_hit"] for h in ha],
       "survivors": ha[-1]["survivors"],
       "spans": [list(sp) for sp in a.backend.spans],
       "tenant_hits": ha[-1]["tenant_cache_hits"]}
print(json.dumps(out))
"""
    res = _run_sub(code)
    assert res["marks"] == [False, False, True, False, False, False]
    assert res["survivors"] == [0, 1, 2] and len(res["spans"]) == 3
    # per-tenant cache re-capture: the rebind drops every tenant's entries,
    # both slots re-capture at the new geometry, then hits resume
    assert res["hits"] == [False, False, False, False, True, True], res
    assert all(h > 0 for h in res["tenant_hits"]), res
    # isolation holds THROUGH the shrink: untouched tenants bit-equal
    assert res["a"][0] == res["b"][0]
    assert res["a"][1] == res["b"][1]
    assert res["a"][2] != res["b"][2]


def test_deprecated_persistence_shims_warn(tmp_path):
    """Satellite: ``export_params``/``load`` survive as thin shims over the
    canonical ``backend.export_params()`` / ``_load_into`` — each warns once
    and delegates (in-process: the pjit backend runs on one device)."""
    import warnings

    import jax
    import pytest

    from repro.api import RingSession
    from repro.configs import TrainConfig, get_config

    cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                            d_model=128, d_ff=256)
    tc = TrainConfig(learning_rate=1e-3, batch_size=1, seq_len=16)
    sess = RingSession.create(cfg, tc, backend="pjit")
    with pytest.warns(DeprecationWarning, match="export_params"):
        old = sess.export_params()
    canonical = sess.backend.export_params()
    assert jax.tree.structure(old) == jax.tree.structure(canonical)
    path = str(tmp_path / "ck")
    sess.save(path)
    with pytest.warns(DeprecationWarning, match="restore"):
        sess.load(path)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # canonical path: no warning
        RingSession.restore(path, cfg, tc)
