"""Packed-conveyor Phase A (+ compressed cache entries) vs the fused oracle.

Pins the tentpole contracts of the packed executor (core/pipeline.py
``ring_phase_a_packed`` + core/executor.py ``packed=True``):

  (a) equivalence — for every (S, M, boundary, Lps) in the grid, the packed
      executor's losses and exported params match the per-owner-scan fused
      oracle at the f32 pins (1e-5 / 1e-3), across a boundary walk (the
      conveyor is re-built per boundary; each microbatch sees the same op
      sequence as the scan, only the conveyor length differs),
  (b) cache interplay — capture -> cached transitions and boundary-drop
      invalidation behave identically under the packed conveyor, for every
      storage dtype: f32/bf16 entries stay at the 1e-5/1e-3 pins (lossless
      round-trips for a bf16 model), int8 at calibrated tolerances (per-row
      symmetric quantization, ~0.4% row error compounding over 8 rounds),
  (c) executable shape — packing changes the Phase-A *interior* of the
      direct/capture executables, not their count or the (boundary, mode)
      naming.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PRELUDE = """
import json
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.models import params as P
from repro.core.executor import RingExecutor

def fresh_params(cfg):
    params = P.materialize(P.param_defs(cfg), jax.random.key(0))
    ad = params["blocks"][0]["adapter"]
    ad["w_up"] = 0.02 * jax.random.normal(jax.random.key(9), ad["w_up"].shape,
                                          jnp.float32).astype(ad["w_up"].dtype)
    return params

def batch(cfg, S, M, mb, seq, k=0):
    t = jax.random.randint(jax.random.key(10 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    l = jax.random.randint(jax.random.key(20 + k), (S, M, mb, seq), 0,
                           cfg.vocab_size)
    return t, l

f32 = lambda x: x.astype(jnp.float32)
maxerr = lambda a, b: max(jax.tree.leaves(jax.tree.map(
    lambda x, y: float(jnp.abs(f32(x) - f32(y)).max()), a, b)))
"""


def test_packed_matches_scan_across_grid():
    """(a) + (c): three (S, M, Lps) geometries, each walking its boundary
    schedule (interval = S steps -> one drop per round), packed vs scan."""
    code = PRELUDE + """
out = {}
# (S, M, lps): 4 stages 1 block each; 2 stages 2 blocks each (stage-aligned
# boundary != block boundary); 4 stages with a single microbatch (conveyor
# degenerates to S + F - 1 ticks).
for S, M, lps in ((4, 3, 1), (2, 2, 2), (4, 1, 1)):
    cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=S * lps,
                                            d_model=128, d_ff=256)
    mb, seq = 1, 32
    tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=S, n_microbatches=M,
                     batch_size=mb, seq_len=seq)
    mesh = compat.make_mesh((S,), ("stage",),
                            devices=jax.devices()[:S])
    tokens, labels = batch(cfg, S, M, mb, seq)
    rec = {"scan_loss": [], "packed_loss": [], "b": []}
    with compat.set_mesh(mesh):
        scan = RingExecutor(cfg, tc, mesh, fresh_params(cfg), S, M,
                            packed=False)
        pk = RingExecutor(cfg, tc, mesh, fresh_params(cfg), S, M, packed=True)
        for r in range(3):
            ms = RingExecutor.materialize_metrics(scan.round(tokens, labels))
            mp = RingExecutor.materialize_metrics(pk.round(tokens, labels))
            rec["scan_loss"].append(ms["loss"])
            rec["packed_loss"].append(mp["loss"])
            assert ms["boundary"] == mp["boundary"]
            rec["b"].append(mp["boundary"])
        rec["param_err"] = maxerr(scan.export_params(), pk.export_params())
        rec["packed_compiles"] = pk.compile_counts()
        rec["scan_compiles"] = scan.compile_counts()
    out[f"S{S}_M{M}_lps{lps}"] = rec
print(json.dumps(out))
"""
    res = _run_sub(code)
    for name, rec in res.items():
        for sl, pl in zip(rec["scan_loss"], rec["packed_loss"]):
            assert abs(sl - pl) < 1e-5, (name, rec)
        assert rec["param_err"] < 1e-3, (name, rec)
        # (c) same executable set, same naming — packing is interior-only
        assert rec["packed_compiles"] == rec["scan_compiles"], (name, rec)
        assert all(k.endswith("/direct") for k in rec["packed_compiles"])


def test_packed_cache_dtypes_across_boundary_drop():
    """(b): packed capture -> cached transitions + boundary-drop invalidation
    per storage dtype, all against the scan-Phase-A uncached oracle.

    2 slots x 8 rounds, boundary dropping once mid-run (interval = 4 rounds'
    steps => capture, capture, hit, hit per boundary).  f32/bf16 round-trip a
    bf16 model's activations losslessly -> the 1e-5/1e-3 pins hold; int8 is
    pinned at calibrated tolerances (loss 8e-2 / params 2e-1, ~2x the drift
    measured on this grid) plus a sanity floor that it still tracks."""
    code = PRELUDE + """
S, M, mb, seq = 4, 3, 1, 32
cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
tc = TrainConfig(learning_rate=1e-3, unfreeze_interval=4 * S, n_microbatches=M,
                 batch_size=mb, seq_len=seq)
mesh = compat.make_mesh((4,), ("stage",))
batches = [batch(cfg, S, M, mb, seq, k=0), batch(cfg, S, M, mb, seq, k=1)]
out = {}
with compat.set_mesh(mesh):
    plain = RingExecutor(cfg, tc, mesh, fresh_params(cfg), S, M, packed=False)
    plain_loss = []
    for r in range(8):
        t, l = batches[r % 2]
        plain_loss.append(
            RingExecutor.materialize_metrics(plain.round(t, l))["loss"])
    pp = plain.export_params()
    for dt in ("f32", "bf16", "int8"):
        drv = RingExecutor(cfg, tc, mesh, fresh_params(cfg), S, M,
                           cache_capacity=2, cache_dtype=dt, packed=True)
        losses, hits, bounds = [], [], []
        for r in range(8):
            t, l = batches[r % 2]
            m = RingExecutor.materialize_metrics(drv.round(t, l, slot=r % 2))
            losses.append(m["loss"])
            hits.append(m["cache_hit"])
            bounds.append(m["boundary"])
        st = drv.cache.stats()
        out[dt] = {
            "max_loss_err": max(abs(a - b)
                                for a, b in zip(plain_loss, losses)),
            "param_err": maxerr(pp, drv.export_params()),
            "hits": hits, "bounds": bounds,
            "stats": {k: st[k] for k in
                      ("cache_hits", "cache_misses", "cache_invalidations",
                       "cache_bypasses", "cache_dtype",
                       "cache_bytes_per_entry")},
            "compiles": drv.compile_counts(),
        }
print(json.dumps(out))
"""
    res = _run_sub(code)
    tol = {"f32": (1e-5, 1e-3), "bf16": (1e-5, 1e-3), "int8": (8e-2, 2e-1)}
    f32_bytes = res["f32"]["stats"]["cache_bytes_per_entry"]
    for dt, rec in res.items():
        lt, pt = tol[dt]
        assert rec["max_loss_err"] < lt, (dt, rec)
        assert rec["param_err"] < pt, (dt, rec)
        # cache behavior is dtype-independent: capture, capture, hit, hit
        # around the drop, one invalidation, no bypasses
        assert rec["hits"] == [False, False, True, True] * 2, (dt, rec)
        assert rec["bounds"] == [3] * 4 + [2] * 4, (dt, rec)
        st = rec["stats"]
        assert st["cache_hits"] == 4 and st["cache_misses"] == 4
        assert st["cache_invalidations"] == 1 and st["cache_bypasses"] == 0
        assert st["cache_dtype"] == dt
        # one capture + one cached executable per boundary, packed or not
        assert rec["compiles"] == {f"{b}/{m}": 1 for b in (3, 2)
                                   for m in ("capture", "cached")}, (dt, rec)
    # the compression claim: bf16 halves, int8 ~quarters the bytes per entry
    assert res["bf16"]["stats"]["cache_bytes_per_entry"] * 2 == f32_bytes
    assert res["int8"]["stats"]["cache_bytes_per_entry"] < 0.3 * f32_bytes
    # int8 still *tracks* (sanity floor: not garbage)
    assert res["int8"]["max_loss_err"] > 0  # lossy, so not bit-equal
