"""End-to-end RingAda: 4 edge devices in a ring, collaborative fine-tuning.

This is the paper's Fig. 2 in runnable form, driven through the
``repro.api.RingSession`` facade: 4 (virtual) devices each hold a span of
transformer blocks + their adapters and a private local dataset; training
rounds rotate the initiator, activations travel the ring via ppermute,
backward early-stops at the terminator stage, and the unfreeze schedule
deepens every k steps.  The ``cached`` backend adds the frozen-trunk
activation cache: epoch 0 captures the boundary activations per batch slot,
later epochs skip Phase A; each boundary drop invalidates the cache.

    python examples/ring_finetune.py          # sets its own XLA device flag
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, "src")

from repro.api import LoggingCallback, RingSession
from repro.configs import TrainConfig, get_config


def main():
    cfg = get_config("mbert-squad").reduced(n_layers=4, repeats=4,
                                            head_out=None)
    tc = TrainConfig(learning_rate=5e-3, batch_size=2, seq_len=64,
                     n_microbatches=4, unfreeze_interval=12, warmup_steps=4)
    print(f"ring of 4 devices, {cfg.n_layers} blocks -> 1 block/device, "
          f"{tc.n_microbatches} microbatches in flight")
    # one session call replaces the old hand-wired driver: fused executor +
    # activation cache over 4 epoch-stable batch slots, metrics sync only
    # every log_every rounds (async dispatch preserved).
    sess = RingSession.create(cfg, tc, backend="cached", n_stages=4,
                              slots_per_epoch=4, cache_dtype="bf16")
    hist = sess.run(16, log_every=4, callbacks=[LoggingCallback(every=4)])
    best = min(h["loss"] for h in hist)
    steps = hist[-1]["step"]
    wall = hist[-1]["wall_s"]
    last = hist[-1]
    print(f"loss {hist[0]['loss']:.4f} -> {last['loss']:.4f} "
          f"(best {best:.4f}) in {wall:.1f}s "
          f"({steps / wall:.2f} steps/s incl. compile); "
          f"final boundary={last['boundary']}, "
          f"{last['compile_count']} executables")
    print(f"activation cache: {last['cache_hits']:.0f} hits / "
          f"{last['cache_misses']:.0f} misses "
          f"(hit rate {last['cache_hit_rate']:.0%}), "
          f"{last['cache_invalidations']:.0f} boundary-drop invalidation(s), "
          f"{last['cache_dtype']} entries at "
          f"{last['cache_bytes_per_entry'] / 1024:.0f} KiB each")


if __name__ == "__main__":
    main()
