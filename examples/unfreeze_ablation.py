"""Ablation: how the unfreeze interval k trades compute for convergence.

Sweeps the paper's k (steps per adapter unfreeze) and reports final loss,
activation-memory footprint per boundary (from memory_analysis), and wall time
— the compute/quality trade-off behind Fig. 3(a).

    PYTHONPATH=src python examples/unfreeze_ablation.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.launch.train import train_pjit
from repro.models import params as prm
from repro.optim import adamw


def main():
    cfg = get_config("stablelm-3b").reduced(n_layers=8, repeats=8)
    steps = 32

    print("=== memory vs boundary (compiled temp bytes) ===")
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    opt = adamw.init(training.full_trainable(params))
    import jax.numpy as jnp
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 64), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (8, 64), 0,
                                          cfg.vocab_size)}
    tc = TrainConfig()
    for b in (0, 4, 7):
        step = jax.jit(training.make_train_step(cfg, tc, b))
        mem = step.lower(params, opt, batch).compile().memory_analysis()
        print(f"  boundary={b} (depth {cfg.repeats - b:2d}): "
              f"temp={mem.temp_size_in_bytes / 2**20:6.1f} MiB")

    print("=== convergence vs unfreeze interval k ===")
    for k in (4, 8, 1_000_000):
        label = f"k={k}" if k < 1_000_000 else "k=inf (top-1 only)"
        tc = TrainConfig(learning_rate=2e-3, batch_size=8, seq_len=64,
                         unfreeze_interval=k, warmup_steps=2)
        out = train_pjit(cfg, tc, steps=steps, log_every=steps,
                         scheme="ringada", log=lambda *a: None)
        h = out["history"][-1]
        print(f"  {label:22s} final_loss={h['loss']:.4f} "
              f"final_depth={h['depth']:2d} wall={out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
