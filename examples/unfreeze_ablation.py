"""Ablation: how the unfreeze schedule trades compute for convergence.

Three sections, all driven through the ``repro.api.RingSession`` facade:

  1. activation-memory footprint per boundary (compiled temp bytes) — the
     paper's early-stopped-backprop memory claim,
  2. the paper's k-sweep (unfreeze interval vs final loss / wall time),
  3. **policy ablation**: the paper's fixed ``IntervalPolicy`` vs the
     adaptive ``LossPlateauPolicy`` (unfreeze the next adapter when the
     smoothed loss plateaus), end-to-end through the same session API, with
     the per-step boundary trace printed — monotone by contract, and the
     final state round-tripped through the canonical persistence surface
     (``session.save(path)`` / ``RingSession.restore``).

    PYTHONPATH=src python examples/unfreeze_ablation.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import jax

from repro.api import IntervalPolicy, LossPlateauPolicy, RingSession
from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.models import params as prm
from repro.optim import adamw


def compress_trace(bs):
    """[3,3,3,2,2,0] -> '3 x3 -> 2 x2 -> 0 x1' (run-length, readable)."""
    runs = []
    for b in bs:
        if runs and runs[-1][0] == b:
            runs[-1][1] += 1
        else:
            runs.append([b, 1])
    return " -> ".join(f"{b} x{n}" for b, n in runs)


def main():
    cfg = get_config("stablelm-3b").reduced(n_layers=8, repeats=8)
    steps = 32

    print("=== memory vs boundary (compiled temp bytes) ===")
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    opt = adamw.init(training.full_trainable(params))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 64), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (8, 64), 0,
                                          cfg.vocab_size)}
    tc = TrainConfig()
    for b in (0, 4, 7):
        step = jax.jit(training.make_train_step(cfg, tc, b))
        mem = step.lower(params, opt, batch).compile().memory_analysis()
        print(f"  boundary={b} (depth {cfg.repeats - b:2d}): "
              f"temp={mem.temp_size_in_bytes / 2**20:6.1f} MiB")

    print("=== convergence vs unfreeze interval k (IntervalPolicy) ===")
    for k in (4, 8, 1_000_000):
        label = f"k={k}" if k < 1_000_000 else "k=inf (top-1 only)"
        tc = TrainConfig(learning_rate=2e-3, batch_size=8, seq_len=64,
                         unfreeze_interval=k, warmup_steps=2)
        sess = RingSession.create(cfg, tc, backend="pjit")
        hist = sess.run(steps, log_every=steps)
        h = hist[-1]
        print(f"  {label:22s} final_loss={h['loss']:.4f} "
              f"final_depth={h['depth']:2d} wall={h['wall_s']:.1f}s")

    print("=== policy ablation: IntervalPolicy vs LossPlateauPolicy ===")
    tc = TrainConfig(learning_rate=2e-3, batch_size=8, seq_len=64,
                     unfreeze_interval=8, warmup_steps=2)
    policies = {
        "interval(k=8)": IntervalPolicy(initial_depth=1, interval=8),
        "plateau(p=2)": LossPlateauPolicy(initial_depth=1, patience=2,
                                          min_rel_improve=5e-3),
    }
    for name, policy in policies.items():
        sess = RingSession.create(cfg, tc, backend="pjit", policy=policy)
        hist = sess.run(steps, log_every=steps)
        trace = [h["boundary"] for h in hist]
        assert all(a >= b for a, b in zip(trace, trace[1:])), \
            f"boundary trace not monotone: {trace}"
        h = hist[-1]
        print(f"  {name:14s} final_loss={h['loss']:.4f} "
              f"final_depth={h['depth']:2d} wall={h['wall_s']:.1f}s "
              f"compiles={h['compile_count']}")
        print(f"    boundary trace (monotone): {compress_trace(trace)}")

    # canonical persistence: one save(path), one restore — the resumed
    # session picks up the step counter, boundary, and Adam moments exactly
    # (tests/test_api_session.py pins the bit-identical continuation).
    ck = os.path.join(tempfile.mkdtemp(prefix="ablation_"), "ck")
    sess.save(ck)
    re = RingSession.restore(ck, cfg, tc, backend="pjit",
                             policy=policies["plateau(p=2)"])
    assert re.step_count == sess.step_count
    print(f"saved + restored at step {re.step_count}")


if __name__ == "__main__":
    main()
