"""Quickstart: RingAda adapter fine-tuning with scheduled layer unfreezing.

Runs in ~2 minutes on CPU: builds a reduced StableLM-family model, fine-tunes
its adapters with the paper's top-down unfreezing schedule (watch ``boundary``
fall as depth grows), checkpoints through the canonical persistence surface
(``session.save(path)``), then serves a few greedy tokens from the tuned
model.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.launch.train import train_pjit
from repro.models import transformer as tfm


def main():
    cfg = get_config("stablelm-3b").reduced()
    print(f"arch={cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model} "
          f"adapter_m={cfg.adapter.bottleneck}")

    tc = TrainConfig(learning_rate=2e-3, batch_size=8, seq_len=64,
                     unfreeze_interval=8,      # paper uses 40; shrunk for demo
                     warmup_steps=2)
    out = train_pjit(cfg, tc, steps=32, log_every=4, scheme="ringada")

    # the canonical persistence surface: session.save(path) snapshots
    # params + Adam moments + policy + data cursor (RingSession.restore
    # resumes it bit-identically); export_params() is the full canonical
    # tree serving consumes.
    sess = out["session"]
    ck = os.path.join(tempfile.mkdtemp(prefix="quickstart_"), "ck")
    sess.save(ck)
    print(f"checkpointed to {ck} (resume with RingSession.restore)")
    params = sess.backend.export_params()

    # greedy continuation from the fine-tuned model
    prompt = jnp.array([[7, 42, 199, 23, 5, 77, 3, 11]], dtype=jnp.int32)
    _, cache = tfm.prefill(params, prompt, cfg, seq_len=64)
    tok = jnp.argmax(tfm.forward(params, prompt, cfg)[0][:, -1], -1
                     )[:, None].astype(jnp.int32)
    gen = []
    for _ in range(12):
        gen.append(int(tok[0, 0]))
        logits, cache = tfm.decode_step(params, tok, cache, cfg)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print("greedy continuation:", gen)


if __name__ == "__main__":
    main()
