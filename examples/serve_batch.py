"""Batched serving example: continuous batching over mixed-length prompts.

Shows the serving half of the framework: prefill with ring-buffer KV caches
(sliding-window archs keep O(window) memory), then step-wise batched decode.

    PYTHONPATH=src python examples/serve_batch.py --arch hymba-1.5b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchServer, Request
from repro.models import params as prm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b",
                    help="any registered arch (reduced variant is served)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"serving {args.arch} (reduced): window={cfg.sliding_window} "
          f"family={cfg.family}")
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 20))
                                    ).astype(np.int32),
                    args.max_new) for i in range(args.requests)]
    server = BatchServer(cfg, params, slots=4, horizon=64)
    results = server.run(reqs)
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid]}")


if __name__ == "__main__":
    main()
