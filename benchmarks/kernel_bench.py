"""Kernel benchmarks: Pallas (interpret) vs jnp reference — correctness +
analytic roofline terms for the TPU target (no TPU wall-clock on CPU)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.roofline import HBM_BW, PEAK_FLOPS


def _t(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(log=print) -> Dict:
    out = {}

    # --- adapter fusion: arithmetic-intensity analysis -----------------------
    T, D, m = 4096, 2560, 64
    h = jax.random.normal(jax.random.key(0), (T, D), jnp.bfloat16)
    wd = 0.05 * jax.random.normal(jax.random.key(1), (D, m), jnp.float32)
    wu = 0.05 * jax.random.normal(jax.random.key(2), (m, D), jnp.float32)
    flops = 4 * T * D * m
    bytes_unfused = (3 * T * D + 2 * T * m + 2 * D * m) * 2   # 3x h streams
    bytes_fused = (2 * T * D + 2 * D * m) * 2                  # h in + out
    jref = jax.jit(lambda *a: ref.adapter_fused(*a))
    t_ref = _t(jref, h, wd, wu)
    got = ops.adapter_fused(h, wd, wu)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - jref(h, wd, wu).astype(jnp.float32)).max())
    out["adapter_fused"] = {
        "shape": f"T{T}xD{D}xm{m}", "max_err": err,
        "jnp_cpu_us": t_ref * 1e6,
        "tpu_mem_term_unfused_us": bytes_unfused / HBM_BW * 1e6,
        "tpu_mem_term_fused_us": bytes_fused / HBM_BW * 1e6,
        "tpu_compute_term_us": flops / PEAK_FLOPS * 1e6,
        "fusion_speedup_bound": bytes_unfused / bytes_fused,
    }
    log(f"  adapter_fused err={err:.4f} "
        f"mem-bound speedup bound={bytes_unfused/bytes_fused:.2f}x")

    # --- rwkv chunked scan: flops vs sequential ------------------------------
    N, S, hd, L = 8, 512, 64, 32
    keys = jax.random.split(jax.random.key(3), 6)
    r, k, v = (jax.random.normal(keys[i], (N, S, hd), jnp.float32)
               for i in range(3))
    lw = -jnp.exp(0.5 * jax.random.normal(keys[3], (N, S, hd)) - 1.0)
    u = 0.5 * jax.random.normal(keys[4], (N, 1, hd))
    s0 = jnp.zeros((N, hd, hd))
    jr = jax.jit(lambda *a: ref.rwkv_scan(*a))
    t_seq = _t(jr, r, k, v, lw, u, s0)
    o1, s1 = ops.rwkv_scan(r, k, v, lw, u, s0)
    o2, s2 = jr(r, k, v, lw, u, s0)
    err = float(jnp.abs(o1 - o2).max())
    # chunked kernel: matmul flops per chunk ~ 3*L^2*hd + 2*L*hd^2
    chunk_flops = (S // L) * (3 * L * L * hd + 4 * L * hd * hd) * N
    out["rwkv_scan"] = {
        "shape": f"N{N}xS{S}xhd{hd}", "max_err": err,
        "seq_scan_cpu_us": t_seq * 1e6,
        "chunked_tpu_compute_us": chunk_flops / PEAK_FLOPS * 1e6,
        "hbm_roundtrips_seq": S, "hbm_roundtrips_chunked": S // L,
    }
    log(f"  rwkv_scan err={err:.5f} HBM roundtrips {S} -> {S // L}")

    # --- flash attention: memory traffic bound -------------------------------
    Nq, Sq, hd2, g = 8, 1024, 128, 4
    q = jax.random.normal(jax.random.key(5), (Nq, Sq, hd2), jnp.bfloat16)
    kk = jax.random.normal(jax.random.key(6), (Nq // g, Sq, hd2), jnp.bfloat16)
    vv = jax.random.normal(jax.random.key(7), (Nq // g, Sq, hd2), jnp.bfloat16)
    got = ops.flash_attention(q, kk, vv, group=g)
    want = jnp.stack([ref.flash_attention(q[i:i+1], kk[i//g:i//g+1],
                                          vv[i//g:i//g+1])[0]
                      for i in range(Nq)])
    err = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    bytes_naive = (Nq * Sq * Sq * 2) * 2 + 3 * Nq * Sq * hd2 * 2  # probs to HBM
    bytes_flash = (3 * Nq * Sq * hd2 + Nq * Sq * hd2) * 2
    out["flash_attention"] = {
        "shape": f"N{Nq}xS{Sq}xhd{hd2} gqa{g}", "max_err": err,
        "bytes_naive": bytes_naive, "bytes_flash": bytes_flash,
        "traffic_reduction": bytes_naive / bytes_flash,
    }
    log(f"  flash_attention err={err:.4f} "
        f"traffic cut {bytes_naive/bytes_flash:.1f}x")
    return out
