"""Ring-pipeline benchmark.

Four sections:
  1. analytic tick counts per unfreeze depth (incl. the cached Phase-A skip
     and the packed conveyor's per-round totals), cross-checked against the
     discrete-event simulator (``ringada_packed`` with ``n_owners=S``),
  2. simulated round time + utilization (discrete-event MPMD model),
  3. **fused-vs-reference-vs-cached**: real wall-clock steps/sec, executable
     counts and per-executable memory (incl. donation aliasing) for the fused
     ``RingExecutor`` against the unfused ``RingTrainer``, plus
       * packed-conveyor Phase A vs the per-owner scan (direct rounds at the
         steady boundary — the first-visit/capture cost the conveyor cuts),
       * multi-tenant packing (per-tenant steps/sec at T in {1, 4} on the
         tenant conveyor — the fill/drain bubble amortizes over T),
       * the frozen-trunk activation cache's steady state per storage dtype
         (f32 / bf16 / int8: bytes per entry, hit rate, loss drift),
       * the ``repro.api.RingSession`` facade over the cached path.
     Runs in a subprocess so the parent process keeps its 1-device backend;
     device count comes from ``--devices`` (CI runs 2 and 4).
  4. per-mode executable memory: peak live bytes for packed / scan / cached.

Emits ``BENCH_ring.json`` (schema ``BENCH_ring/v2``; ``--out`` overrides the
path) so the perf trajectory — reference vs fused vs cached, packed-vs-scan
round ratio, cache bytes/entry + hit rate per dtype, compile counts — is
tracked across PRs.  CI uploads it from both a 2- and a 4-device CPU mesh and
gates on ``--check``: cached speedup >= ``CACHED_SPEEDUP_FLOOR`` (1.15 — see
``check_bench_ring``'s threshold note), packed strictly faster than the scan
wherever F >= 2, bf16 entries matching the f32 hit rate at half the bytes,
and the elastic crash-recovery round <= 2x the cached steady round in sim
ticks (the "elastic" section also records the measured recovery-round ms
from a real chaos drill: crash one device mid-run, shrink, re-capture).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_ring.json")

_FUSED_SCRIPT = r"""
import os, time, json
S = int(os.environ.get("BENCH_RING_DEVICES", "4"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={S}"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.core.executor import RingExecutor
from repro.core.ring import RingTrainer
from repro.models import params as prm

# Edge-device regime: tiny per-client microbatches over small adapters — the
# setting where RingAda claims its win and where dispatch / host-sync /
# staged-recompile overheads dominate.
M, mb, seq = 4, 1, 32
cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
mesh = compat.make_mesh((S,), ("stage",))
tokens = jax.random.randint(jax.random.key(1), (S, M, mb, seq), 0,
                            cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (S, M, mb, seq), 0,
                            cfg.vocab_size)

def fresh_params():
    return prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)

def sync(last):
    if hasattr(last["loss"], "block_until_ready"):
        last["loss"].block_until_ready()             # fused: one final sync

def time_rounds(step, rounds, reps=3):
    # Best-of-reps wall time for `rounds` back-to-back rounds (seconds).
    # Host-CPU collectives jitter by 50%+ run-to-run; a single timing window
    # is too noisy to gate CI on, the min of a few windows is stable.
    best = None
    for _ in range(reps):
        t0 = time.time()
        last = None
        for r in range(rounds):
            last = step(r)
        sync(last)
        dt = time.time() - t0
        best = dt if best is None else min(best, dt)
    return best

out = {"mesh_devices": S}
with compat.set_mesh(mesh):
    # 1. end-to-end: the paper's schedule walks every boundary; each bump
    #    recompiles S executables on the reference path, 1 on the fused path.
    SCHED_ROUNDS = 8
    tc_sched = TrainConfig(learning_rate=1e-3, unfreeze_interval=S,
                           n_microbatches=M, batch_size=mb, seq_len=seq)
    for name, cls in (("reference", RingTrainer), ("fused", RingExecutor)):
        drv = cls(cfg, tc_sched, mesh, fresh_params(), S, M)
        t0 = time.time()
        last = None
        for _ in range(SCHED_ROUNDS):
            last = drv.round(tokens, labels)
        sync(last)
        dt = time.time() - t0
        out.setdefault("schedule", {})[name] = {
            "steps_per_sec": S * SCHED_ROUNDS / dt,
            "wall_s": dt,
            "n_executables": drv.n_executables,
        }

    # 2. steady state: fixed boundary, compile excluded.  'fused' is the
    #    packed conveyor (the default); 'fused_scan' the per-owner Phase A —
    #    their direct-round ratio is the conveyor's win on every
    #    first-visit/capture round (saves (S-1)(F-1) of S(M+F-1) ticks).
    ROUNDS = 16
    tc_fix = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                         n_microbatches=M, batch_size=mb, seq_len=seq)
    drivers = {}
    for name, mk in (
            ("reference", lambda: RingTrainer(cfg, tc_fix, mesh,
                                              fresh_params(), S, M)),
            ("fused", lambda: RingExecutor(cfg, tc_fix, mesh, fresh_params(),
                                           S, M, packed=True)),
            ("fused_scan", lambda: RingExecutor(cfg, tc_fix, mesh,
                                                fresh_params(), S, M,
                                                packed=False))):
        drv = mk()
        t0 = time.time()
        drv.round(tokens, labels)                    # warmup: compile
        compile_s = time.time() - t0
        dt = time_rounds(lambda r: drv.round(tokens, labels), ROUNDS)
        rec = {"steps_per_sec": S * ROUNDS / dt, "compile_s": compile_s,
               "round_ms": 1e3 * dt / ROUNDS,
               "n_executables": drv.n_executables}
        stats = jax.devices()[0].memory_stats() or {}
        if "peak_bytes_in_use" in stats:
            rec["device_peak_bytes"] = stats["peak_bytes_in_use"]
        out.setdefault("steady", {})[name] = rec
        drivers[name] = drv
    out["steady_boundary"] = drivers["fused"].boundary_at(0)
    out["frozen_stages"] = (out["steady_boundary"]
                            // drivers["fused"].lps)
    out["n_micro"] = M
    out["lps"] = drivers["fused"].lps
    out["packed_scan_ratio"] = (out["steady"]["fused"]["round_ms"]
                                / out["steady"]["fused_scan"]["round_ms"])

    # 2b. multi-tenant packing: T adapter sets on ONE ring.  The tenant
    #     conveyor chains T*S*M microbatches through a single fill/drain
    #     (T*S*M + F - 1 ticks), so the bubble amortizes over T and the
    #     per-tenant round cost must stay well under 2x the solo round
    #     (gated in check_bench_ring; the analytic per-tenant cost is
    #     S*M + (F-1)/T ticks, i.e. *below* 1x solo in tick units).
    T_HI = 4
    ROUNDS_T = 8
    tok4 = jnp.broadcast_to(tokens[:, None], (S, T_HI) + tokens.shape[1:])
    lab4 = jnp.broadcast_to(labels[:, None], (S, T_HI) + labels.shape[1:])
    drv4 = RingExecutor(cfg, tc_fix, mesh, fresh_params(), S, M,
                        tenants=T_HI, packed=True)
    t0 = time.time()
    drv4.round(tok4, lab4)                           # warmup: compile
    compile4_s = time.time() - t0
    dt4 = time_rounds(lambda r: drv4.round(tok4, lab4), ROUNDS_T)
    t1_ms = out["steady"]["fused"]["round_ms"]       # same geometry, T=1
    t4_ms = 1e3 * dt4 / ROUNDS_T
    out["tenants"] = {
        "T1": {"round_ms": t1_ms,
               "per_tenant_steps_per_sec":
                   out["steady"]["fused"]["steps_per_sec"]},
        "T4": {"round_ms": t4_ms, "compile_s": compile4_s,
               "per_tenant_steps_per_sec": S * ROUNDS_T / dt4,
               "n_executables": drv4.n_executables},
        # per-tenant share of the T=4 round vs the whole T=1 round
        "per_tenant_round_ratio": (t4_ms / T_HI) / t1_ms,
    }

    # 3. actcache steady state at the highest scheduled boundary (F = S-1),
    #    per storage dtype: epoch 0 captures each slot's boundary
    #    activations, every later epoch enters the pipeline at stage F (no
    #    embed / all_gather / Phase A), dequantizing on device.  The f32 run
    #    doubles as the headline 'cached' record.
    N_SLOTS = 2
    for dt_name in ("f32", "bf16", "int8"):
        drv = RingExecutor(cfg, tc_fix, mesh, fresh_params(), S, M,
                           cache_capacity=N_SLOTS, cache_dtype=dt_name)
        t0 = time.time()
        for sl in range(N_SLOTS):
            drv.round(tokens, labels, slot=sl)   # capture epoch (+compile)
        last = drv.round(tokens, labels, slot=0)     # first hit: compile cached
        sync(last)
        compile_s = time.time() - t0
        dt = time_rounds(
            lambda r: drv.round(tokens, labels, slot=r % N_SLOTS), ROUNDS)
        last = drv.round(tokens, labels, slot=0)
        stats = drv.cache.stats()
        rec = {
            "steps_per_sec": S * ROUNDS / dt, "compile_s": compile_s,
            "round_ms": 1e3 * dt / ROUNDS,
            "n_executables": drv.n_executables,
            "boundary": drv.boundary_at(0),
            "final_loss": float(last["loss"]),
            "cache_hit_rate": stats["cache_hit_rate"],
            "cache_hits": stats["cache_hits"],
            "cache_misses": stats["cache_misses"],
            "bytes_per_entry": stats["cache_bytes_per_entry"],
            "buffer_bytes": stats["cache_buffer_bytes"],
            "compile_counts": drv.compile_counts(),
        }
        out.setdefault("cache_dtypes", {})[dt_name] = rec
        if dt_name == "f32":
            out["steady"]["cached"] = rec
    for dt_name, rec in out["cache_dtypes"].items():
        rec["loss_drift_vs_f32"] = abs(
            rec["final_loss"] - out["cache_dtypes"]["f32"]["final_loss"])

    # 4. the RingSession facade over the same cached path: the API adds only
    #    thin host-side dispatch over the same executables, so its steady
    #    state must track the raw driver (the facade-overhead ratio is
    #    recorded in BENCH_ring.json to catch regressions).
    from repro.api import BenchCaptureCallback, RingSession
    sess = RingSession.create(cfg, tc_fix, backend="cached", n_stages=S,
                              slots_per_epoch=N_SLOTS)
    sess.run(N_SLOTS + 1, log_every=N_SLOTS + 1)   # capture epoch + compile
    cap = BenchCaptureCallback()
    t0 = time.time()
    sess.run(ROUNDS, log_every=ROUNDS, callbacks=[cap])
    dt = time.time() - t0
    out["steady"]["session_cached"] = {
        "steps_per_sec": S * ROUNDS / dt,
        "round_ms": 1e3 * dt / ROUNDS,
        "n_executables": cap.result()["compile_count"],
        "cache_hit_rate": cap.result().get("cache_hit_rate", 0.0),
    }

    # per-executable memory analysis: the fused step aliases (donates) params
    # + moments; packed holds the whole [S*M] conveyor live (temp bytes) where
    # the scan holds one owner's [M]; the cached executable takes the ring
    # buffer instead of tokens.
    def mem_record(ma):
        return {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,   # donated: no second copy
            "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }

    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    for name in ("fused", "fused_scan"):
        ex = drivers[name]
        b = ex.boundary_at(0)
        ma = ex._fn(b).lower(
            abstract(ex.stage_blocks), abstract(ex.shared),
            abstract(ex.opt_state), abstract(tokens),
            abstract(labels)).compile().memory_analysis()
        if ma is not None:
            key = "packed" if name == "fused" else "scan"
            out.setdefault("mode_memory", {})[key] = mem_record(ma)
            if name == "fused":
                out["fused_memory"] = mem_record(ma)
    ref = drivers["reference"]
    b = drivers["fused"].boundary_at(0)
    ma_ref = ref._fn(0, b).lower(
        abstract(ref.stage_blocks), abstract(ref.shared),
        abstract(tokens), abstract(labels)).compile().memory_analysis()
    if ma_ref is not None:
        out["reference_memory"] = mem_record(ma_ref)

out["speedup"] = (out["schedule"]["fused"]["steps_per_sec"]
                  / out["schedule"]["reference"]["steps_per_sec"])
out["steady_speedup"] = (out["steady"]["fused"]["steps_per_sec"]
                         / out["steady"]["reference"]["steps_per_sec"])
out["cached_speedup_vs_fused"] = (out["steady"]["cached"]["steps_per_sec"]
                                  / out["steady"]["fused"]["steps_per_sec"])
out["session_facade_ratio"] = (out["steady"]["session_cached"]["steps_per_sec"]
                               / out["steady"]["cached"]["steps_per_sec"])
print(json.dumps(out))
"""

_ELASTIC_SCRIPT = r"""
import os, time, json
S = int(os.environ.get("BENCH_RING_DEVICES", "4"))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={S}"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses
from repro.api import RingSession
from repro.configs import TrainConfig, get_config

# Chaos recovery drill: steady cached ring at S stages, crash the last
# device mid-run, measure the checkpoint-free recovery round (shrink +
# moment restack + cache re-capture, INCLUDING the new geometry's compiles)
# against the cached steady rounds on either side of it.
cfg = dataclasses.replace(get_config("stablelm-3b").reduced(
    n_layers=2 * S, repeats=2 * S, d_model=64, d_ff=128), dtype="float32")
tc = TrainConfig(learning_rate=1e-3, batch_size=S, seq_len=16,
                 unfreeze_interval=10**6, n_stages=S, n_microbatches=2)
KILL = 4                           # the crash fires BEFORE round index KILL
sess = RingSession.create(cfg, tc, backend="cached", slots_per_epoch=1,
                          chaos=f"{KILL}:crash:{S - 1}", elastic=True,
                          log=lambda *a: None)
rows = []
for r in range(KILL + 5):
    t0 = time.perf_counter()
    m = sess.step().materialize()
    rows.append({"ms": (time.perf_counter() - t0) * 1e3,
                 "hit": bool(m.cache_hit),
                 "changed": bool(m.extras.get("layout_changed"))})
rec = next(i for i, row in enumerate(rows) if row["changed"])
refill = next(i for i in range(rec, len(rows)) if rows[i]["hit"]) - rec
print(json.dumps({
    "stages": S,
    "survivors": list(m.extras["survivors"]),
    "spans": [list(sp) for sp in sess.backend.spans],
    "recovery_round_ms": rows[rec]["ms"],
    # cheapest hit round on each side (the first hit at a geometry still
    # pays that geometry's cached-executable compile, min() skips it)
    "steady_round_ms_before": min(r["ms"] for r in rows[1:rec] if r["hit"]),
    "steady_round_ms_after": min(r["ms"] for r in rows[rec + refill + 1:]),
    "rounds_to_cache_refill_measured": refill,
}))
"""


def bench_fused_vs_reference(log=print, devices: int = 4) -> Dict:
    """Run the fused-vs-reference comparison in an n-device subprocess."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               BENCH_RING_DEVICES=str(devices))
    env.pop("XLA_FLAGS", None)
    try:
        res = subprocess.run([sys.executable, "-c", _FUSED_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=1800)
    except subprocess.TimeoutExpired:
        return {"skipped": "timeout"}
    if res.returncode != 0:
        return {"skipped": res.stderr[-2000:]}
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for name in ("reference", "fused"):
        r = out["schedule"][name]
        log(f"  schedule {name:10s}: {r['steps_per_sec']:6.2f} steps/s "
            f"end-to-end ({r['wall_s']:.1f}s, {r['n_executables']} "
            f"executables over all boundaries)")
    for name in ("reference", "fused", "fused_scan", "cached"):
        r = out["steady"][name]
        log(f"  steady   {name:10s}: {r['steps_per_sec']:6.2f} steps/s "
            f"({r['round_ms']:.0f} ms/round, compile {r['compile_s']:.1f}s, "
            f"{r['n_executables']} executable(s))")
    log(f"  packed conveyor: {out['packed_scan_ratio']:.2f}x the scan's "
        f"round time at F={out['frozen_stages']} "
        f"(first-visit/capture rounds)")
    ten = out.get("tenants")
    if ten:
        log(f"  tenants: T=1 {ten['T1']['per_tenant_steps_per_sec']:6.2f} "
            f"steps/s/tenant ({ten['T1']['round_ms']:.0f} ms/round), "
            f"T=4 {ten['T4']['per_tenant_steps_per_sec']:6.2f} "
            f"({ten['T4']['round_ms']:.0f} ms/round) — per-tenant share "
            f"{ten['per_tenant_round_ratio']:.2f}x the solo round")
    for dt_name, r in out.get("cache_dtypes", {}).items():
        log(f"  cache[{dt_name:5s}]: {r['bytes_per_entry']:>8d} B/entry, "
            f"hit rate {r['cache_hit_rate']:.0%}, "
            f"{r['round_ms']:.0f} ms/round, "
            f"loss drift vs f32 {r['loss_drift_vs_f32']:.2e}")
    r = out["steady"]["session_cached"]
    log(f"  steady   session   : {r['steps_per_sec']:6.2f} steps/s "
        f"({r['round_ms']:.0f} ms/round) — RingSession facade at "
        f"{out['session_facade_ratio']:.2f}x the raw cached driver")
    for key in ("fused_memory", "reference_memory"):
        if key in out:
            fm = out[key]
            log(f"  {key.split('_')[0]:9s} executable: "
                f"peak={fm['peak_bytes'] / 2**20:.1f} MiB "
                f"(donation aliases {fm['alias_bytes'] / 2**20:.1f} MiB)")
    for key, fm in out.get("mode_memory", {}).items():
        log(f"  mode {key:6s} executable: peak={fm['peak_bytes'] / 2**20:.1f} "
            f"MiB (temps {fm['temp_bytes'] / 2**20:.1f} MiB)")
    c = out["steady"]["cached"]
    log(f"  actcache: hit rate {c['cache_hit_rate']:.0%} at boundary "
        f"{c['boundary']}, compiles {c['compile_counts']}")
    log(f"  speedup: {out['speedup']:.2f}x end-to-end, "
        f"{out['steady_speedup']:.2f}x steady-state fused-vs-reference, "
        f"{out['cached_speedup_vs_fused']:.2f}x steady-state cached-vs-fused")
    return out


def bench_elastic(log=print, devices: int = 4) -> Dict:
    """Run the measured chaos recovery drill in an n-device subprocess:
    crash one device mid-run under ``--elastic`` and price the
    checkpoint-free recovery round against its neighboring cached rounds."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               BENCH_RING_DEVICES=str(devices))
    env.pop("XLA_FLAGS", None)
    try:
        res = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=1800)
    except subprocess.TimeoutExpired:
        return {"skipped": "timeout"}
    if res.returncode != 0:
        return {"skipped": res.stderr[-2000:]}
    out = json.loads(res.stdout.strip().splitlines()[-1])
    log(f"  crash {out['stages']} -> {len(out['spans'])} stages: recovery "
        f"round {out['recovery_round_ms']:.0f} ms (cached steady "
        f"{out['steady_round_ms_before']:.0f} ms before, "
        f"{out['steady_round_ms_after']:.0f} ms after), cache refilled in "
        f"{out['rounds_to_cache_refill_measured']} round(s)")
    return out


def _tick_ledger(S: int, M: int, frozen: int) -> Dict[str, float]:
    """Phase-A tick closed forms + discrete-event cross-check for the
    measured bench geometry (S stages, M microbatches, F frozen stages)."""
    from repro.core.partition import DeviceProfile
    from repro.core.pipeline import pipeline_tick_counts
    from repro.core.simulator import LayerProfile, SimConfig, simulate_round

    t_scan = pipeline_tick_counts(S, M, boundary=frozen, lps=1)
    t_packed = pipeline_tick_counts(S, M, boundary=frozen, lps=1, packed=True)
    row: Dict[str, float] = {
        "phase_a_round_ticks_scan": t_scan["phase_a_round_ticks"],
        "phase_a_round_ticks_packed": t_packed["phase_a_round_ticks"],
        "phase_a_saved_ticks": t_packed["phase_a_saved_ticks"],
    }
    if 0 < frozen < S:
        fz = LayerProfile(1.0, 0.0, 1.0, 1.0, 0.1, 0.0)
        hot = LayerProfile(0.0, 0.0, 1.0, 1.0, 0.1, 0.0)
        lay = [fz] * frozen + [hot] * (S - frozen)
        dev = [DeviceProfile(1.0, 4096)] * S
        sim = SimConfig(n_layers=S, n_devices=S, n_microbatches=M)
        row["sim_round_scan"] = simulate_round(
            "ringada", sim, lay, dev, unfreeze_depth=S - frozen,
            n_owners=S).time_per_round_s
        row["sim_round_packed"] = simulate_round(
            "ringada_packed", sim, lay, dev, unfreeze_depth=S - frozen,
            n_owners=S).time_per_round_s
    return row


def check_hetero(out_or_bench: Dict, gate) -> None:
    """Gate: the speed-weighted partition beats uniform on the skewed mesh."""
    het = out_or_bench.get("hetero")
    if not het:
        return
    gate(het["weighted_round_s"] < het["uniform_round_s"],
         f"speed-weighted spans {het['weighted_spans']} round "
         f"{het['weighted_round_s']:.3f}s < uniform "
         f"{het['uniform_round_s']:.3f}s on skewed mesh "
         f"{het['device_speeds']}")


def write_bench_ring(out: Dict, path: str, log=print) -> Optional[Dict]:
    """Condense the measured section into BENCH_ring.json (schema v2).

    Machine-readable perf trajectory (tracked across PRs, uploaded by CI
    from both the 2- and 4-device meshes): steady-state steps/sec for
    reference / fused(packed) / scan / cached, the packed-vs-scan round
    ratio with its tick-count ledger, per-dtype cache bytes/entry + hit
    rate, per-mode executable peak bytes, and per-boundary compile counts.
    """
    fvr = out.get("fused_vs_reference", {})
    if "steady" not in fvr:
        log(f"  BENCH_ring.json NOT written ({path}): bench skipped "
            f"({fvr.get('skipped', 'no data')[:200]})")
        return None
    steady = fvr["steady"]
    cached = steady["cached"]
    frozen = fvr.get("frozen_stages", 0)
    # tick ledger for the MEASURED geometry (the section-1 table uses the
    # simulator's 12-block model — different M/lps; publishing those numbers
    # next to packed_scan_ratio would compare two configurations)
    tick_row = _tick_ledger(fvr.get("mesh_devices", 4),
                            fvr.get("n_micro", 4), frozen)
    bench = {
        "schema": "BENCH_ring/v2",
        "mesh_devices": fvr.get("mesh_devices", 4),
        "boundary": cached["boundary"],
        "frozen_stages": frozen,
        "steady_steps_per_sec": {
            name: steady[name]["steps_per_sec"]
            for name in ("reference", "fused", "fused_scan", "cached")},
        "steady_round_ms": {
            name: steady[name]["round_ms"]
            for name in ("reference", "fused", "fused_scan", "cached")},
        "packed_scan_ratio": fvr.get("packed_scan_ratio"),
        "phase_a_ticks": {
            "packed": tick_row.get("phase_a_round_ticks_packed"),
            "scan": tick_row.get("phase_a_round_ticks_scan"),
            "saved": tick_row.get("phase_a_saved_ticks"),
            "simulated_packed": tick_row.get("sim_round_packed"),
            "simulated_scan": tick_row.get("sim_round_scan"),
        },
        "cache_dtypes": {
            name: {k: r.get(k) for k in
                   ("bytes_per_entry", "buffer_bytes", "cache_hit_rate",
                    "round_ms", "steps_per_sec", "loss_drift_vs_f32")}
            for name, r in fvr.get("cache_dtypes", {}).items()},
        "mode_memory_peak_bytes": {
            k: v.get("peak_bytes")
            for k, v in fvr.get("mode_memory", {}).items()},
        "speedup_fused_vs_reference": fvr["steady_speedup"],
        "speedup_cached_vs_fused": fvr["cached_speedup_vs_fused"],
        "speedup_schedule_fused_vs_reference": fvr["speedup"],
        "session_facade_ratio": fvr.get("session_facade_ratio"),
        "session_steps_per_sec": fvr["steady"].get(
            "session_cached", {}).get("steps_per_sec"),
        # multi-tenant packing: per-tenant steps/sec at T in {1, 4} and the
        # per-tenant share of the T=4 round vs the solo round (gated < 2.0)
        "tenants": fvr.get("tenants"),
        "cache_hit_rate": cached["cache_hit_rate"],
        "compile_counts": cached["compile_counts"],
        "n_executables": {
            name: steady[name]["n_executables"]
            for name in ("reference", "fused", "cached")},
        # simulated skewed-mesh result: speed-weighted assign_layers spans
        # vs the uniform split (deterministic -> gated by --check)
        "hetero": out.get("hetero"),
        # checkpoint-free crash recovery: sim-tick prices (gated) plus the
        # measured recovery-round ms from the chaos drill subprocess
        "elastic": out.get("elastic"),
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    log(f"  wrote {path}: cached {bench['steady_steps_per_sec']['cached']:.2f} "
        f"steps/s = {bench['speedup_cached_vs_fused']:.2f}x fused "
        f"({bench['cache_hit_rate']:.0%} hit rate), packed/scan "
        f"{bench['packed_scan_ratio']:.2f}")
    return bench


CACHED_SPEEDUP_FLOOR = 1.15


def check_bench_ring(path: str, log=print) -> bool:
    """The CI regression gate over a written BENCH_ring.json.

    Fails when the cached steady state stops clearly beating the fused
    executor, when the packed conveyor stops beating the per-owner scan on
    first-visit/capture rounds (only meaningful at F >= 2 — at F <= 1 there
    are no cross-owner bubbles to save, so the ratio gate is skipped),
    when bf16 entries stop matching the f32 hit rate at half the bytes,
    when the T=4 tenant conveyor's per-tenant round stops staying under 2x
    the solo round (the bubble must amortize over tenants), when the
    speed-weighted partition stops beating the uniform split on the skewed
    simulated mesh (deterministic discrete-event model, no jitter), or when
    a checkpoint-free crash recovery (one full re-capture round at the
    survivor geometry) stops costing <= 2x the cached steady round that
    follows — also gated in deterministic sim ticks, not wall-clock.

    Threshold note: the v1 bench's headline "cached = 3x fused" came from
    single timing windows, which on host-CPU collectives jitter by 50%+ and
    systematically flattered the second-measured driver; under the v2
    best-of-3 methodology the honest steady-state ratio at (S=4, M=4, F=3)
    is ~1.3x — structurally capped near 1.6x, since the cached round still
    pays all of Phase B's forward AND backward ticks and the round-fixed
    optimizer/dispatch cost.  The floor is set below the measured ratio with
    margin; the packed gate (a same-executable A/B) is the tight one.
    """
    with open(path) as f:
        bench = json.load(f)
    ok = True

    def gate(cond, msg):
        nonlocal ok
        log(f"  [{'PASS' if cond else 'FAIL'}] {msg}")
        ok = ok and cond

    sp = bench.get("speedup_cached_vs_fused") or 0.0
    gate(sp >= CACHED_SPEEDUP_FLOOR,
         f"speedup_cached_vs_fused {sp:.2f} >= {CACHED_SPEEDUP_FLOOR}")
    frozen = bench.get("frozen_stages", 0)
    ratio = bench.get("packed_scan_ratio")
    if frozen >= 2 and ratio is not None:
        gate(ratio < 1.0,
             f"packed/scan round-ms ratio {ratio:.3f} < 1.0 at F={frozen}")
    else:
        log(f"  [skip] packed/scan ratio gate (F={frozen} < 2: no "
            f"cross-owner bubbles to pack away)")
    dts = bench.get("cache_dtypes", {})
    if "f32" in dts and "bf16" in dts:
        f32d, bf = dts["f32"], dts["bf16"]
        gate(bf["bytes_per_entry"] * 2 == f32d["bytes_per_entry"],
             f"bf16 entry bytes {bf['bytes_per_entry']} == half of f32's "
             f"{f32d['bytes_per_entry']}")
        gate(bf["cache_hit_rate"] == f32d["cache_hit_rate"],
             f"bf16 hit rate {bf['cache_hit_rate']:.0%} == f32's at half "
             f"the bytes")
        drift = bf.get("loss_drift_vs_f32", 1.0)
        gate(drift < 1e-3, f"bf16 loss drift vs f32 {drift:.2e} < 1e-3")
    ten = bench.get("tenants")
    if ten:
        tr = ten["per_tenant_round_ratio"]
        gate(tr < 2.0,
             f"T=4 per-tenant packed round is {tr:.2f}x the T=1 round "
             f"(< 2.0: the tenant conveyor amortizes the fill/drain "
             f"bubble instead of re-paying it per tenant)")
    check_hetero(bench, gate)
    el = bench.get("elastic")
    if el and el.get("recovery_round_ticks") is not None:
        gate(el["recovery_round_ticks"] <= 2 * el["steady_round_ticks"],
             f"checkpoint-free recovery round {el['recovery_round_ticks']} "
             f"ticks <= 2x the post-shrink cached steady round "
             f"{el['steady_round_ticks']} (boundary {el['boundary']}, "
             f"refill {el['rounds_to_cache_refill']} round(s))")
    return ok


def run(log=print, out_path: str = DEFAULT_OUT, devices: int = 4) -> Dict:
    out = {}
    S, M, lps = devices, 8, 12 // devices      # 12 blocks over the mesh
    from repro.core.partition import DeviceProfile
    from repro.core.pipeline import pipeline_tick_counts
    from repro.core.simulator import LayerProfile, SimConfig, simulate_round

    ticks = {}
    for frozen_stages in range(S):
        t = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps)
        tc = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps,
                                  cached=True)
        t["fwd_ticks_cached"] = tc["fwd_ticks"]
        t.pop("phase_a_round_ticks")
        t.pop("phase_a_saved_ticks")
        # closed forms + discrete-event cross-check (unit-cost frozen
        # blocks, free hot blocks and links: engine time == tick count)
        t.update(_tick_ledger(S, M, frozen_stages))
        if 0 < frozen_stages < S:
            assert t["sim_round_scan"] == t["phase_a_round_ticks_scan"]
            assert t["sim_round_packed"] == t["phase_a_round_ticks_packed"]
        ticks[f"frozen_{frozen_stages}"] = t
        log(f"  frozen_stages={frozen_stages}: fwd={t['fwd_ticks']} "
            f"(cached {tc['fwd_ticks']}) bwd={t['bwd_ticks']} ticks; "
            f"phase A/round scan={t['phase_a_round_ticks_scan']} "
            f"packed={t['phase_a_round_ticks_packed']} "
            f"(saves {t['phase_a_saved_ticks']})")
    out["tick_counts"] = ticks

    layers = [LayerProfile(0.01, 0.02, 20.0, 30.0, 0.6, 2.0)] * 12
    sim_devices = [DeviceProfile(1.0, 4096)] * S
    sim = SimConfig(n_layers=12, n_devices=S, n_microbatches=M)

    # heterogeneous mesh: the paper's speed-weighted assignment
    # (assign_layers) vs the uniform split, on a skewed simulated mesh.
    # Deterministic discrete-event model, so CI gates on it (--check):
    # the speed-weighted partition must beat uniform.
    from repro.core.partition import (parse_device_profiles, span_sizes,
                                      spans_from_profiles)
    skew = ([1.0, 0.5, 2.0, 1.0] * ((S + 3) // 4))[:S]
    het_devices = [DeviceProfile(compute_speed=sp, memory_mb=4096)
                   for sp in skew]
    costs = [l.fwd_s + l.bwd_s for l in layers]
    w_spans = spans_from_profiles(12, parse_device_profiles(skew),
                                  layer_costs=costs)
    r_uni = simulate_round("ringada", sim, layers, het_devices,
                           unfreeze_depth=6)
    r_wtd = simulate_round("ringada", sim, layers, het_devices,
                           unfreeze_depth=6, spans=list(w_spans))
    out["hetero"] = {
        "device_speeds": skew,
        "weighted_spans": [list(sp) for sp in w_spans],
        "uniform_round_s": r_uni.time_per_round_s,
        "weighted_round_s": r_wtd.time_per_round_s,
        "speedup": r_uni.time_per_round_s / r_wtd.time_per_round_s,
        "uniform_peak_mb": r_uni.max_memory_mb,
        "weighted_peak_mb": r_wtd.max_memory_mb,
    }
    log(f"  hetero mesh (speeds {skew}): weighted spans "
        f"{list(span_sizes(w_spans))} round={r_wtd.time_per_round_s:.3f}s "
        f"vs uniform {r_uni.time_per_round_s:.3f}s "
        f"({out['hetero']['speedup']:.2f}x)")

    # elastic: price the checkpoint-free crash recovery in sim ticks on the
    # same 12-block mesh at the section-2 depth-6 operating point.  A crash
    # costs one full re-capture round at the survivor geometry (the cache
    # was rebound), then cached rounds resume — deterministic, so --check
    # gates recovery <= 2x the post-shrink steady round.
    from repro.core.simulator import predict_recovery
    survivors = [DeviceProfile(1.0, 4096)] * max(S - 1, 1)
    rec = predict_recovery(12, survivors, M, boundary=6, packed=True,
                           slots_per_epoch=1)
    out["elastic"] = {
        "survivor_spans": [list(sp) for sp in rec["spans"]],
        "boundary": rec["boundary"],
        "frozen_stages": rec["frozen_stages"],
        "recovery_round_ticks": rec["recovery_round_ticks"],
        "steady_round_ticks": rec["steady_round_ticks"],
        "rounds_to_cache_refill": rec["rounds_to_cache_refill"],
    }
    log(f"  elastic crash {S} -> {len(rec['spans'])} units: recovery round "
        f"{rec['recovery_round_ticks']} ticks vs cached steady "
        f"{rec['steady_round_ticks']} (boundary 6 -> {rec['boundary']}, "
        f"refill in {rec['rounds_to_cache_refill']} round(s))")

    util = {}
    for depth in (1, 3, 6, 12):
        r = simulate_round("ringada", sim, layers, sim_devices,
                           unfreeze_depth=depth)
        rc = simulate_round("ringada_cached", sim, layers, sim_devices,
                            unfreeze_depth=depth)
        busy = sum(r.device_busy_s.values())
        util[f"depth_{depth}"] = {
            "round_s": r.time_per_round_s,
            "round_s_cached": rc.time_per_round_s,
            "utilization": busy / (r.time_per_round_s * S),
        }
        log(f"  depth={depth:2d}: round={r.time_per_round_s:.3f}s "
            f"(cached {rc.time_per_round_s:.3f}s) "
            f"util={busy / (r.time_per_round_s * S):.2%}")
    out["simulated_rounds"] = util

    log(f"fused RingExecutor vs reference RingTrainer vs packed vs actcache "
        f"({devices} host devices):")
    out["fused_vs_reference"] = bench_fused_vs_reference(log, devices)
    log(f"chaos recovery drill ({devices} -> {devices - 1} host devices):")
    out["elastic"]["measured"] = bench_elastic(log, devices)
    if out_path:
        out["bench_ring"] = write_bench_ring(out, out_path, log)
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_ring.json ('' to skip)")
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual CPU devices for the measured section "
                         "(CI runs 2 and 4)")
    ap.add_argument("--check", default=None, metavar="BENCH_JSON",
                    help="gate mode: validate a written BENCH_ring.json "
                         "against the regression thresholds and exit "
                         "nonzero on failure (no benchmarks are run)")
    args = ap.parse_args()
    if args.check:
        sys.exit(0 if check_bench_ring(args.check) else 1)
    print(json.dumps(run(out_path=args.out, devices=args.devices), indent=1))
