"""Ring-pipeline benchmark: tick counts + simulated utilization per unfreeze
depth, plus (if >=4 devices available) real shard_map round wall-times."""
from __future__ import annotations

from typing import Dict

import jax

from repro.core.partition import DeviceProfile
from repro.core.pipeline import pipeline_tick_counts
from repro.core.simulator import LayerProfile, SimConfig, simulate_round


def run(log=print) -> Dict:
    out = {}
    S, M, lps = 4, 8, 3           # 12 blocks over 4 stages
    ticks = {}
    for frozen_stages in range(S):
        t = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps)
        ticks[f"frozen_{frozen_stages}"] = t
        log(f"  frozen_stages={frozen_stages}: fwd={t['fwd_ticks']} "
            f"bwd={t['bwd_ticks']} ticks")
    out["tick_counts"] = ticks

    layers = [LayerProfile(0.01, 0.02, 20.0, 30.0, 0.6, 2.0)] * 12
    devices = [DeviceProfile(1.0, 4096)] * 4
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=M)
    util = {}
    for depth in (1, 3, 6, 12):
        r = simulate_round("ringada", sim, layers, devices,
                           unfreeze_depth=depth)
        busy = sum(r.device_busy_s.values())
        util[f"depth_{depth}"] = {
            "round_s": r.time_per_round_s,
            "utilization": busy / (r.time_per_round_s * 4),
        }
        log(f"  depth={depth:2d}: round={r.time_per_round_s:.3f}s "
            f"util={busy / (r.time_per_round_s * 4):.2%}")
    out["simulated_rounds"] = util
    return out
