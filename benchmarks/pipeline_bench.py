"""Ring-pipeline benchmark.

Three sections:
  1. analytic tick counts per unfreeze depth,
  2. simulated round time + utilization (discrete-event MPMD model),
  3. **fused-vs-reference**: real wall-clock steps/sec, executable counts and
     per-executable memory (incl. donation aliasing) for the fused
     ``RingExecutor`` against the unfused ``RingTrainer`` on a 4-(host-)device
     ring.  Runs in a subprocess so the parent process keeps its 1-device
     backend; invoke directly with ``python benchmarks/pipeline_bench.py`` or
     through ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FUSED_SCRIPT = r"""
import os, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.core.executor import RingExecutor
from repro.core.ring import RingTrainer
from repro.models import params as prm

# Edge-device regime: tiny per-client microbatches over small adapters — the
# setting where RingAda claims its win and where dispatch / host-sync /
# staged-recompile overheads dominate.
S, M, mb, seq = 4, 4, 1, 32
cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
mesh = compat.make_mesh((S,), ("stage",))
tokens = jax.random.randint(jax.random.key(1), (S, M, mb, seq), 0,
                            cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (S, M, mb, seq), 0,
                            cfg.vocab_size)

def fresh_params():
    return prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)

def sync(last):
    if hasattr(last["loss"], "block_until_ready"):
        last["loss"].block_until_ready()             # fused: one final sync

out = {}
with compat.set_mesh(mesh):
    # 1. end-to-end: the paper's schedule walks every boundary; each bump
    #    recompiles S executables on the reference path, 1 on the fused path.
    SCHED_ROUNDS = 8
    tc_sched = TrainConfig(learning_rate=1e-3, unfreeze_interval=S,
                           n_microbatches=M, batch_size=mb, seq_len=seq)
    for name, cls in (("reference", RingTrainer), ("fused", RingExecutor)):
        drv = cls(cfg, tc_sched, mesh, fresh_params(), S, M)
        t0 = time.time()
        last = None
        for _ in range(SCHED_ROUNDS):
            last = drv.round(tokens, labels)
        sync(last)
        dt = time.time() - t0
        out.setdefault("schedule", {})[name] = {
            "steps_per_sec": S * SCHED_ROUNDS / dt,
            "wall_s": dt,
            "n_executables": drv.n_executables,
        }

    # 2. steady state: fixed boundary, compile excluded.
    ROUNDS = 16
    tc_fix = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                         n_microbatches=M, batch_size=mb, seq_len=seq)
    for name, cls in (("reference", RingTrainer), ("fused", RingExecutor)):
        drv = cls(cfg, tc_fix, mesh, fresh_params(), S, M)
        t0 = time.time()
        drv.round(tokens, labels)                    # warmup: compile
        compile_s = time.time() - t0
        t0 = time.time()
        last = None
        for _ in range(ROUNDS):
            last = drv.round(tokens, labels)
        sync(last)
        dt = time.time() - t0
        rec = {"steps_per_sec": S * ROUNDS / dt, "compile_s": compile_s,
               "round_ms": 1e3 * dt / ROUNDS,
               "n_executables": drv.n_executables}
        stats = jax.devices()[0].memory_stats() or {}
        if "peak_bytes_in_use" in stats:
            rec["device_peak_bytes"] = stats["peak_bytes_in_use"]
        out.setdefault("steady", {})[name] = rec

    # per-executable memory analysis: the fused step aliases (donates) params +
    # moments; the reference path re-materializes grads/outputs per dispatch
    # and runs its optimizer un-donated on the host.
    def mem_record(ma):
        return {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,   # donated: no second copy
            "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }

    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    ex = RingExecutor(cfg, tc_fix, mesh, fresh_params(), S, M, donate=True)
    b = ex.boundary_at(0)
    ma = ex._fn(b).lower(
        abstract(ex.stage_blocks), abstract(ex.shared),
        abstract(ex.opt_state), abstract(tokens),
        abstract(labels)).compile().memory_analysis()
    if ma is not None:
        out["fused_memory"] = mem_record(ma)
    ref = RingTrainer(cfg, tc_fix, mesh, fresh_params(), S, M)
    ma_ref = ref._fn(0, b).lower(
        abstract(ref.stage_blocks), abstract(ref.shared),
        abstract(tokens), abstract(labels)).compile().memory_analysis()
    if ma_ref is not None:
        out["reference_memory"] = mem_record(ma_ref)

out["speedup"] = (out["schedule"]["fused"]["steps_per_sec"]
                  / out["schedule"]["reference"]["steps_per_sec"])
out["steady_speedup"] = (out["steady"]["fused"]["steps_per_sec"]
                         / out["steady"]["reference"]["steps_per_sec"])
print(json.dumps(out))
"""


def bench_fused_vs_reference(log=print) -> Dict:
    """Run the fused-vs-reference comparison in a 4-device subprocess."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    try:
        res = subprocess.run([sys.executable, "-c", _FUSED_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=900)
    except subprocess.TimeoutExpired:
        return {"skipped": "timeout"}
    if res.returncode != 0:
        return {"skipped": res.stderr[-2000:]}
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for name in ("reference", "fused"):
        r = out["schedule"][name]
        log(f"  schedule {name:9s}: {r['steps_per_sec']:6.2f} steps/s "
            f"end-to-end ({r['wall_s']:.1f}s, {r['n_executables']} "
            f"executables over all boundaries)")
    for name in ("reference", "fused"):
        r = out["steady"][name]
        log(f"  steady   {name:9s}: {r['steps_per_sec']:6.2f} steps/s "
            f"({r['round_ms']:.0f} ms/round, compile {r['compile_s']:.1f}s, "
            f"{r['n_executables']} executable(s))")
    for key in ("fused_memory", "reference_memory"):
        if key in out:
            fm = out[key]
            log(f"  {key.split('_')[0]:9s} executable: "
                f"peak={fm['peak_bytes'] / 2**20:.1f} MiB "
                f"(donation aliases {fm['alias_bytes'] / 2**20:.1f} MiB)")
    log(f"  speedup: {out['speedup']:.2f}x end-to-end, "
        f"{out['steady_speedup']:.2f}x steady-state")
    return out


def run(log=print) -> Dict:
    out = {}
    S, M, lps = 4, 8, 3           # 12 blocks over 4 stages
    from repro.core.partition import DeviceProfile
    from repro.core.pipeline import pipeline_tick_counts
    from repro.core.simulator import LayerProfile, SimConfig, simulate_round

    ticks = {}
    for frozen_stages in range(S):
        t = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps)
        ticks[f"frozen_{frozen_stages}"] = t
        log(f"  frozen_stages={frozen_stages}: fwd={t['fwd_ticks']} "
            f"bwd={t['bwd_ticks']} ticks")
    out["tick_counts"] = ticks

    layers = [LayerProfile(0.01, 0.02, 20.0, 30.0, 0.6, 2.0)] * 12
    devices = [DeviceProfile(1.0, 4096)] * 4
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=M)
    util = {}
    for depth in (1, 3, 6, 12):
        r = simulate_round("ringada", sim, layers, devices,
                           unfreeze_depth=depth)
        busy = sum(r.device_busy_s.values())
        util[f"depth_{depth}"] = {
            "round_s": r.time_per_round_s,
            "utilization": busy / (r.time_per_round_s * 4),
        }
        log(f"  depth={depth:2d}: round={r.time_per_round_s:.3f}s "
            f"util={busy / (r.time_per_round_s * 4):.2%}")
    out["simulated_rounds"] = util

    log("fused RingExecutor vs reference RingTrainer (4 host devices):")
    out["fused_vs_reference"] = bench_fused_vs_reference(log)
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(ROOT, "src"))
    print(json.dumps(run(), indent=1))
