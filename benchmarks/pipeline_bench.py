"""Ring-pipeline benchmark.

Three sections:
  1. analytic tick counts per unfreeze depth (incl. the cached Phase-A skip),
  2. simulated round time + utilization (discrete-event MPMD model),
  3. **fused-vs-reference-vs-cached**: real wall-clock steps/sec, executable
     counts and per-executable memory (incl. donation aliasing) for the fused
     ``RingExecutor`` against the unfused ``RingTrainer``, plus the
     frozen-trunk activation cache's steady state (Phase A skipped) at the
     highest scheduled boundary, on a 4-(host-)device ring — and the
     ``repro.api.RingSession`` facade over the same cached path (the
     facade-overhead ratio guards against the API growing a hot-loop cost).
     Runs in a subprocess so the parent process keeps its 1-device backend;
     invoke
     directly with ``python benchmarks/pipeline_bench.py`` or through
     ``benchmarks/run.py``.

Emits ``BENCH_ring.json`` (machine-readable; ``--out`` overrides the path) so
the steady-state perf trajectory — reference vs PR-1 fused vs cached, cache
hit rate, per-boundary compile counts — is tracked across PRs.  CI uploads it
as a workflow artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_ring.json")

_FUSED_SCRIPT = r"""
import os, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import TrainConfig, get_config
from repro.core.executor import RingExecutor
from repro.core.ring import RingTrainer
from repro.models import params as prm

# Edge-device regime: tiny per-client microbatches over small adapters — the
# setting where RingAda claims its win and where dispatch / host-sync /
# staged-recompile overheads dominate.
S, M, mb, seq = 4, 4, 1, 32
cfg = get_config("stablelm-3b").reduced(n_layers=4, repeats=4,
                                        d_model=128, d_ff=256)
mesh = compat.make_mesh((S,), ("stage",))
tokens = jax.random.randint(jax.random.key(1), (S, M, mb, seq), 0,
                            cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (S, M, mb, seq), 0,
                            cfg.vocab_size)

def fresh_params():
    return prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)

def sync(last):
    if hasattr(last["loss"], "block_until_ready"):
        last["loss"].block_until_ready()             # fused: one final sync

out = {}
with compat.set_mesh(mesh):
    # 1. end-to-end: the paper's schedule walks every boundary; each bump
    #    recompiles S executables on the reference path, 1 on the fused path.
    SCHED_ROUNDS = 8
    tc_sched = TrainConfig(learning_rate=1e-3, unfreeze_interval=S,
                           n_microbatches=M, batch_size=mb, seq_len=seq)
    for name, cls in (("reference", RingTrainer), ("fused", RingExecutor)):
        drv = cls(cfg, tc_sched, mesh, fresh_params(), S, M)
        t0 = time.time()
        last = None
        for _ in range(SCHED_ROUNDS):
            last = drv.round(tokens, labels)
        sync(last)
        dt = time.time() - t0
        out.setdefault("schedule", {})[name] = {
            "steps_per_sec": S * SCHED_ROUNDS / dt,
            "wall_s": dt,
            "n_executables": drv.n_executables,
        }

    # 2. steady state: fixed boundary, compile excluded.
    ROUNDS = 16
    tc_fix = TrainConfig(learning_rate=1e-3, unfreeze_interval=10**6,
                         n_microbatches=M, batch_size=mb, seq_len=seq)
    for name, cls in (("reference", RingTrainer), ("fused", RingExecutor)):
        drv = cls(cfg, tc_fix, mesh, fresh_params(), S, M)
        t0 = time.time()
        drv.round(tokens, labels)                    # warmup: compile
        compile_s = time.time() - t0
        t0 = time.time()
        last = None
        for _ in range(ROUNDS):
            last = drv.round(tokens, labels)
        sync(last)
        dt = time.time() - t0
        rec = {"steps_per_sec": S * ROUNDS / dt, "compile_s": compile_s,
               "round_ms": 1e3 * dt / ROUNDS,
               "n_executables": drv.n_executables}
        stats = jax.devices()[0].memory_stats() or {}
        if "peak_bytes_in_use" in stats:
            rec["device_peak_bytes"] = stats["peak_bytes_in_use"]
        out.setdefault("steady", {})[name] = rec

    # 3. actcache steady state at the highest scheduled boundary (F = S-1):
    #    epoch 0 captures each slot's boundary activations, every later epoch
    #    enters the pipeline at stage F (no embed / all_gather / Phase A).
    N_SLOTS = 2
    drv = RingExecutor(cfg, tc_fix, mesh, fresh_params(), S, M,
                       cache_capacity=N_SLOTS)
    t0 = time.time()
    for sl in range(N_SLOTS):
        drv.round(tokens, labels, slot=sl)       # capture epoch (+compile)
    last = drv.round(tokens, labels, slot=0)     # first hit: compile cached
    sync(last)
    compile_s = time.time() - t0
    t0 = time.time()
    for r in range(ROUNDS):
        last = drv.round(tokens, labels, slot=r % N_SLOTS)
    sync(last)
    dt = time.time() - t0
    stats = drv.cache.stats()
    out["steady"]["cached"] = {
        "steps_per_sec": S * ROUNDS / dt, "compile_s": compile_s,
        "round_ms": 1e3 * dt / ROUNDS,
        "n_executables": drv.n_executables,
        "boundary": drv.boundary_at(0),
        "cache_hit_rate": stats["cache_hit_rate"],
        "cache_hits": stats["cache_hits"],
        "cache_misses": stats["cache_misses"],
        "compile_counts": drv.compile_counts(),
    }

    # 4. the RingSession facade over the same cached path: the API adds only
    #    thin host-side dispatch over the same executables, so its steady
    #    state must track the raw driver (the facade-overhead ratio is
    #    recorded in BENCH_ring.json to catch regressions).
    from repro.api import BenchCaptureCallback, RingSession
    sess = RingSession.create(cfg, tc_fix, backend="cached", n_stages=S,
                              slots_per_epoch=N_SLOTS)
    sess.run(N_SLOTS + 1, log_every=N_SLOTS + 1)   # capture epoch + compile
    cap = BenchCaptureCallback()
    t0 = time.time()
    sess.run(ROUNDS, log_every=ROUNDS, callbacks=[cap])
    dt = time.time() - t0
    out["steady"]["session_cached"] = {
        "steps_per_sec": S * ROUNDS / dt,
        "round_ms": 1e3 * dt / ROUNDS,
        "n_executables": cap.result()["compile_count"],
        "cache_hit_rate": cap.result().get("cache_hit_rate", 0.0),
    }

    # per-executable memory analysis: the fused step aliases (donates) params +
    # moments; the reference path re-materializes grads/outputs per dispatch
    # and runs its optimizer un-donated on the host.
    def mem_record(ma):
        return {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,   # donated: no second copy
            "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }

    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    ex = RingExecutor(cfg, tc_fix, mesh, fresh_params(), S, M, donate=True)
    b = ex.boundary_at(0)
    ma = ex._fn(b).lower(
        abstract(ex.stage_blocks), abstract(ex.shared),
        abstract(ex.opt_state), abstract(tokens),
        abstract(labels)).compile().memory_analysis()
    if ma is not None:
        out["fused_memory"] = mem_record(ma)
    ref = RingTrainer(cfg, tc_fix, mesh, fresh_params(), S, M)
    ma_ref = ref._fn(0, b).lower(
        abstract(ref.stage_blocks), abstract(ref.shared),
        abstract(tokens), abstract(labels)).compile().memory_analysis()
    if ma_ref is not None:
        out["reference_memory"] = mem_record(ma_ref)

out["speedup"] = (out["schedule"]["fused"]["steps_per_sec"]
                  / out["schedule"]["reference"]["steps_per_sec"])
out["steady_speedup"] = (out["steady"]["fused"]["steps_per_sec"]
                         / out["steady"]["reference"]["steps_per_sec"])
out["cached_speedup_vs_fused"] = (out["steady"]["cached"]["steps_per_sec"]
                                  / out["steady"]["fused"]["steps_per_sec"])
out["session_facade_ratio"] = (out["steady"]["session_cached"]["steps_per_sec"]
                               / out["steady"]["cached"]["steps_per_sec"])
print(json.dumps(out))
"""


def bench_fused_vs_reference(log=print) -> Dict:
    """Run the fused-vs-reference comparison in a 4-device subprocess."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    try:
        res = subprocess.run([sys.executable, "-c", _FUSED_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=900)
    except subprocess.TimeoutExpired:
        return {"skipped": "timeout"}
    if res.returncode != 0:
        return {"skipped": res.stderr[-2000:]}
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for name in ("reference", "fused"):
        r = out["schedule"][name]
        log(f"  schedule {name:9s}: {r['steps_per_sec']:6.2f} steps/s "
            f"end-to-end ({r['wall_s']:.1f}s, {r['n_executables']} "
            f"executables over all boundaries)")
    for name in ("reference", "fused", "cached"):
        r = out["steady"][name]
        log(f"  steady   {name:9s}: {r['steps_per_sec']:6.2f} steps/s "
            f"({r['round_ms']:.0f} ms/round, compile {r['compile_s']:.1f}s, "
            f"{r['n_executables']} executable(s))")
    r = out["steady"]["session_cached"]
    log(f"  steady   session  : {r['steps_per_sec']:6.2f} steps/s "
        f"({r['round_ms']:.0f} ms/round) — RingSession facade at "
        f"{out['session_facade_ratio']:.2f}x the raw cached driver")
    for key in ("fused_memory", "reference_memory"):
        if key in out:
            fm = out[key]
            log(f"  {key.split('_')[0]:9s} executable: "
                f"peak={fm['peak_bytes'] / 2**20:.1f} MiB "
                f"(donation aliases {fm['alias_bytes'] / 2**20:.1f} MiB)")
    c = out["steady"]["cached"]
    log(f"  actcache: hit rate {c['cache_hit_rate']:.0%} at boundary "
        f"{c['boundary']}, compiles {c['compile_counts']}")
    log(f"  speedup: {out['speedup']:.2f}x end-to-end, "
        f"{out['steady_speedup']:.2f}x steady-state fused-vs-reference, "
        f"{out['cached_speedup_vs_fused']:.2f}x steady-state cached-vs-fused")
    return out


def write_bench_ring(out: Dict, path: str, log=print) -> Optional[Dict]:
    """Condense the fused-vs-reference-vs-cached section into BENCH_ring.json.

    Machine-readable perf trajectory (tracked across PRs, uploaded by CI):
    steady-state steps/sec for reference / PR-1 fused / cached, the cache hit
    rate, and per-boundary compile counts.
    """
    fvr = out.get("fused_vs_reference", {})
    if "steady" not in fvr:
        log(f"  BENCH_ring.json NOT written ({path}): bench skipped "
            f"({fvr.get('skipped', 'no data')[:200]})")
        return None
    steady = fvr["steady"]
    cached = steady["cached"]
    bench = {
        "schema": "BENCH_ring/v1",
        "mesh_devices": 4,
        "boundary": cached["boundary"],
        "steady_steps_per_sec": {
            name: steady[name]["steps_per_sec"]
            for name in ("reference", "fused", "cached")},
        "steady_round_ms": {
            name: steady[name]["round_ms"]
            for name in ("reference", "fused", "cached")},
        "speedup_fused_vs_reference": fvr["steady_speedup"],
        "speedup_cached_vs_fused": fvr["cached_speedup_vs_fused"],
        "speedup_schedule_fused_vs_reference": fvr["speedup"],
        "session_facade_ratio": fvr.get("session_facade_ratio"),
        "session_steps_per_sec": fvr["steady"].get(
            "session_cached", {}).get("steps_per_sec"),
        "cache_hit_rate": cached["cache_hit_rate"],
        "compile_counts": cached["compile_counts"],
        "n_executables": {
            name: steady[name]["n_executables"]
            for name in ("reference", "fused", "cached")},
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    log(f"  wrote {path}: cached {bench['steady_steps_per_sec']['cached']:.2f} "
        f"steps/s = {bench['speedup_cached_vs_fused']:.2f}x fused "
        f"({bench['cache_hit_rate']:.0%} hit rate)")
    return bench


def run(log=print, out_path: str = DEFAULT_OUT) -> Dict:
    out = {}
    S, M, lps = 4, 8, 3           # 12 blocks over 4 stages
    from repro.core.partition import DeviceProfile
    from repro.core.pipeline import pipeline_tick_counts
    from repro.core.simulator import LayerProfile, SimConfig, simulate_round

    ticks = {}
    for frozen_stages in range(S):
        t = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps)
        tc = pipeline_tick_counts(S, M, boundary=frozen_stages * lps, lps=lps,
                                  cached=True)
        t["fwd_ticks_cached"] = tc["fwd_ticks"]
        ticks[f"frozen_{frozen_stages}"] = t
        log(f"  frozen_stages={frozen_stages}: fwd={t['fwd_ticks']} "
            f"(cached {tc['fwd_ticks']}) bwd={t['bwd_ticks']} ticks")
    out["tick_counts"] = ticks

    layers = [LayerProfile(0.01, 0.02, 20.0, 30.0, 0.6, 2.0)] * 12
    devices = [DeviceProfile(1.0, 4096)] * 4
    sim = SimConfig(n_layers=12, n_devices=4, n_microbatches=M)
    util = {}
    for depth in (1, 3, 6, 12):
        r = simulate_round("ringada", sim, layers, devices,
                           unfreeze_depth=depth)
        rc = simulate_round("ringada_cached", sim, layers, devices,
                            unfreeze_depth=depth)
        busy = sum(r.device_busy_s.values())
        util[f"depth_{depth}"] = {
            "round_s": r.time_per_round_s,
            "round_s_cached": rc.time_per_round_s,
            "utilization": busy / (r.time_per_round_s * 4),
        }
        log(f"  depth={depth:2d}: round={r.time_per_round_s:.3f}s "
            f"(cached {rc.time_per_round_s:.3f}s) "
            f"util={busy / (r.time_per_round_s * 4):.2%}")
    out["simulated_rounds"] = util

    log("fused RingExecutor vs reference RingTrainer vs actcache "
        "(4 host devices):")
    out["fused_vs_reference"] = bench_fused_vs_reference(log)
    if out_path:
        out["bench_ring"] = write_bench_ring(out, out_path, log)
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_ring.json ('' to skip)")
    args = ap.parse_args()
    print(json.dumps(run(out_path=args.out), indent=1))
