"""Aggregate the dry-run artifacts into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_records(path: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def run(path: str = "experiments/dryrun", log=print) -> Dict:
    recs = load_records(path)
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skip"]
    fail = [r for r in recs if r.get("status") == "fail"]
    log(f"  records: {len(ok)} ok / {len(skip)} skip / {len(fail)} fail")

    rows = []
    for r in ok:
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "dominant": ro["dominant"],
            "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
            "collective_s": ro["collective_s"],
            "useful_ratio": ro["useful_ratio"], "mfu": ro["mfu"],
            "peak_gib": r["memory"]["peak_bytes"] / 2**30,
        })
    dominants = {}
    for row in rows:
        dominants[row["dominant"]] = dominants.get(row["dominant"], 0) + 1
    log(f"  dominant terms: {dominants}")
    worst = sorted((r for r in rows if r["mesh"] == "pod16x16"),
                   key=lambda r: r["mfu"])[:5]
    for w in worst:
        log(f"  worst-mfu: {w['arch']}/{w['shape']} mfu={w['mfu']:.3f} "
            f"dominant={w['dominant']}")
    return {"rows": rows, "dominant_histogram": dominants,
            "n_ok": len(ok), "n_skip": len(skip), "n_fail": len(fail)}
