"""Paper Fig. 3: training-loss curves + wall-clock, RingAda vs baselines.

Real (not simulated) CPU training of the reduced mBERT on synthetic per-client
data: 'single' == classic adapter FT (all adapters hot), 'ringada' == scheduled
top-down unfreezing. Reproduces the paper's qualitative claims:
  (a) RingAda's initial convergence is slower but the gap narrows;
  (b) RingAda's time-to-N-steps is smaller (fewer trainables early on).
"""
from __future__ import annotations

from typing import Dict

from repro.configs import TrainConfig, get_config
from repro.launch.train import train_pjit


def run(steps: int = 60, log=print) -> Dict:
    cfg = get_config("mbert-squad").reduced()
    tc = TrainConfig(learning_rate=2e-3, batch_size=8, seq_len=64,
                     unfreeze_interval=max(steps // 6, 4), warmup_steps=2)
    out = {}
    for scheme in ("all_hot", "ringada"):
        res = train_pjit(cfg, tc, steps=steps, log_every=max(steps // 10, 1),
                         scheme=scheme, log=lambda *a: None)
        hist = res["history"]
        out[scheme] = {
            "loss_curve": [(h["step"], round(h["loss"], 4)) for h in hist],
            "final_loss": hist[-1]["loss"],
            "wall_s": res["wall_s"],
        }
        log(f"  {scheme:8s} final_loss={hist[-1]['loss']:.4f} "
            f"wall={res['wall_s']:.1f}s")
    first, last = out["ringada"]["loss_curve"][0], out["ringada"]["loss_curve"][-1]
    out["ringada_converges"] = last[1] < first[1]
    out["gap_narrows"] = (
        abs(out["ringada"]["loss_curve"][-1][1] - out["all_hot"]["loss_curve"][-1][1])
        <= abs(out["ringada"]["loss_curve"][1][1] - out["all_hot"]["loss_curve"][1][1])
        + 0.05)
    return out
