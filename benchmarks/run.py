"""Benchmark harness — one module per paper table/figure.

  table1_sim     -> paper Table I   (memory / time, Single vs PipeAdapter vs RingAda)
  convergence    -> paper Fig. 3    (loss curves + wall clock, real CPU training)
  pipeline_bench -> pipeline ticks + utilization per unfreeze depth
  kernel_bench   -> Pallas kernels: correctness + TPU roofline terms
  roofline_bench -> aggregate dry-run artifacts (EXPERIMENTS.md SS Roofline)

Prints ``name,us_per_call,derived`` CSV rows; writes full JSON artifacts to
experiments/bench/.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,convergence,pipeline,kernels,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="shrink round/step counts for CI")
    args, _ = ap.parse_known_args()
    wanted = set(args.only.split(",")) if args.only else {
        "table1", "convergence", "pipeline", "kernels", "roofline"}

    os.makedirs(RESULTS_DIR, exist_ok=True)
    results = {}
    print("name,us_per_call,derived")

    if "table1" in wanted:
        from benchmarks import table1_sim
        t0 = time.time()
        r = table1_sim.run(rounds=50 if args.fast else 200,
                           log=lambda m: print(f"#{m}"))
        results["table1"] = r
        _emit("table1.single", r["single"]["s_per_round"] * 1e6,
              f"mem={r['single']['peak_memory_mb']:.1f}MB")
        _emit("table1.pipe_adapter", r["pipe_adapter"]["s_per_round"] * 1e6,
              f"mem={r['pipe_adapter']['peak_memory_mb']:.1f}MB;"
              f"speedup={r['speedup_vs_single']['pipe_adapter']:.2f}x")
        _emit("table1.ringada", r["ringada"]["s_per_round"] * 1e6,
              f"mem={r['ringada']['peak_memory_mb']:.1f}MB;"
              f"speedup={r['speedup_vs_single']['ringada']:.2f}x")

    if "convergence" in wanted:
        from benchmarks import convergence
        r = convergence.run(steps=24 if args.fast else 60,
                            log=lambda m: print(f"#{m}"))
        results["convergence"] = r
        for scheme in ("all_hot", "ringada"):
            _emit(f"convergence.{scheme}",
                  r[scheme]["wall_s"] * 1e6 / max(len(r[scheme]["loss_curve"]), 1),
                  f"final_loss={r[scheme]['final_loss']:.4f}")

    if "pipeline" in wanted:
        from benchmarks import pipeline_bench
        r = pipeline_bench.run(log=lambda m: print(f"#{m}"))
        results["pipeline"] = r
        for k, v in r["tick_counts"].items():
            _emit(f"pipeline.ticks.{k}", 0.0,
                  f"fwd={v['fwd_ticks']};bwd={v['bwd_ticks']}")

    if "kernels" in wanted:
        from benchmarks import kernel_bench
        r = kernel_bench.run(log=lambda m: print(f"#{m}"))
        results["kernels"] = r
        _emit("kernels.adapter_fused",
              r["adapter_fused"]["tpu_mem_term_fused_us"],
              f"err={r['adapter_fused']['max_err']:.4f};"
              f"bound={r['adapter_fused']['fusion_speedup_bound']:.2f}x")
        _emit("kernels.rwkv_scan", r["rwkv_scan"]["chunked_tpu_compute_us"],
              f"err={r['rwkv_scan']['max_err']:.5f}")
        _emit("kernels.flash_attention", 0.0,
              f"err={r['flash_attention']['max_err']:.4f};"
              f"traffic={r['flash_attention']['traffic_reduction']:.1f}x")

    if "roofline" in wanted:
        from benchmarks import roofline_bench
        r = roofline_bench.run(log=lambda m: print(f"#{m}"))
        results["roofline"] = {k: v for k, v in r.items() if k != "rows"}
        results["roofline_rows"] = r["rows"]
        _emit("roofline.records", 0.0,
              f"ok={r['n_ok']};skip={r['n_skip']};fail={r['n_fail']}")

    with open(os.path.join(RESULTS_DIR, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# artifacts -> {os.path.relpath(RESULTS_DIR)}/results.json")


if __name__ == "__main__":
    main()
