"""Generate the EXPERIMENTS.md roofline / dry-run tables from recorded JSONs."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(path):
    return [json.load(open(f)) for f in sorted(glob.glob(os.path.join(path, "*.json")))]


def fmt_table(recs, mesh):
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | MFU | peak GiB/chip | compile s |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (order.get(r["shape"], 9), r["arch"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — "
                        f"| — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL: {r.get('error','')[:40]} |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.2e} | "
            f"{ro['memory_s']:.2e} | {ro['collective_s']:.2e} | "
            f"{ro['dominant']} | {min(ro['useful_ratio'],9.99):.2f} | "
            f"{ro['mfu']:.3f} | {r['memory']['peak_bytes']/2**30:.2f} | "
            f"{r['compile_s']:.0f} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    doms = {}
    fits = 0
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
        fits += (r["memory"]["peak_bytes"] / 2**30) <= 16.0
    return (f"{len(ok)} ok / {len(skip)} skip / {len(fail)} fail; "
            f"dominant terms {doms}; {fits}/{len(ok)} under 16 GiB/chip")


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(path)
    print("## Summary:", summary(recs))
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(fmt_table(recs, mesh))
