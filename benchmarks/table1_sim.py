"""Paper Table I: Single vs PipeAdapter vs RingAda (time + memory).

Methodology identical to the paper: per-layer fwd/bwd times are profiled with
real JAX timings of an mBERT block on this host, stored in a lookup table, scaled
to 4 heterogeneous edge devices, and replayed by the discrete-event simulator
over the paper's unfreezing schedule (k = 40 steps per adapter).
"""
from __future__ import annotations

import json
from typing import Dict

from repro.configs import TrainConfig, get_config
from repro.core.partition import DeviceProfile
from repro.core.profiling import head_times, profile_layers
from repro.core.simulator import SimConfig, simulate_training


def run(rounds: int = 200, log=print) -> Dict[str, Dict[str, float]]:
    cfg = get_config("mbert-squad")
    # profile a real mBERT block (batch/seq from the paper's QA setup)
    layers = profile_layers(cfg, batch=8, seq=128)
    ht = head_times(cfg, batch=8, seq=128)
    sim = SimConfig(n_layers=cfg.n_layers, n_devices=4, n_microbatches=8,
                    head_fwd_s=ht["head_fwd_s"], head_bwd_s=ht["head_bwd_s"],
                    head_mb=ht["head_mb"], embed_mb=ht["embed_mb"])
    # 4 heterogeneous edge devices (paper's 4:5:2:3-style asymmetry)
    devices = [DeviceProfile(1.0, 2048, 800), DeviceProfile(1.3, 3072, 1000),
               DeviceProfile(0.6, 1024, 600), DeviceProfile(0.8, 2048, 800)]

    out: Dict[str, Dict[str, float]] = {}
    for scheme in ("single", "pipe_adapter", "ringada"):
        t, mem, curve = simulate_training(
            scheme, sim, layers,
            devices if scheme != "single" else devices[:1],
            rounds=rounds, unfreeze_interval=40, initial_depth=1)
        out[scheme] = {"time_s": t, "peak_memory_mb": mem,
                       "s_per_round": t / rounds}
        log(f"  {scheme:13s} time={t:9.2f}s  mem={mem:8.2f}MB/device")
    out["speedup_vs_single"] = {
        "pipe_adapter": out["single"]["time_s"] / out["pipe_adapter"]["time_s"],
        "ringada": out["single"]["time_s"] / out["ringada"]["time_s"]}
    out["paper_reference"] = {
        "single": {"time_s": 5103.60, "memory_mb": 1035.04},
        "pipe_adapter": {"time_s": 2428.72, "memory_mb": 432.576},
        "ringada": {"time_s": 1793.18, "memory_mb": 373.056}}
    return out
