"""§Perf pair 3 — the paper's own mechanism on the production mesh.

Sweeps the RingAda unfreeze boundary for stablelm-3b x train_4k on the
single-pod mesh and records how the roofline terms + per-chip memory move as
the backward truncates (runs in a subprocess with 512 virtual devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
import jax
from repro import compat
from repro.configs import INPUT_SHAPES, TrainConfig, get_config
from repro.core import training
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh
from repro import roofline as rl

arch = sys.argv[1]
cfg = get_config(arch)
shape = INPUT_SHAPES["train_4k"]
mesh = make_production_mesh()
aspec = inp.act_spec(cfg, shape, mesh)
pspecs = inp.param_specs(cfg, mesh)
aparams = inp.abstract_params(cfg)
batch, bspecs = inp.train_inputs(cfg, shape, mesh)
ospecs = inp.opt_state_specs(cfg, mesh)
ostate = inp.abstract_opt_state(cfg)
tc = TrainConfig()
out = {}
for b in [int(x) for x in sys.argv[2].split(",")]:
    step = training.make_train_step(cfg, tc, b, remat=True, act_spec=aspec,
                                    moe_groups=16)
    with compat.set_mesh(mesh):
        c = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                    out_shardings=(pspecs, ospecs, None),
                    donate_argnums=(0, 1)).lower(aparams, ostate, batch).compile()
    ma = c.memory_analysis()
    cost = compat.cost_analysis(c)
    coll = rl.collective_bytes(c.as_text())
    out[str(b)] = {
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "hlo_flops_per_chip": cost.get("flops", 0.0),
        "hlo_bytes_per_chip": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll["total"],
    }
print(json.dumps(out))
"""


def run(arch: str = "stablelm-3b", boundaries=(0, 16, 24, 31),
        log=print) -> Dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch,
         ",".join(str(b) for b in boundaries)],
        env=env, capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for b, v in out.items():
        log(f"  boundary={b:>2s} (depth {32 - int(b):2d}): "
            f"temp={v['temp_gib']:.2f}GiB "
            f"bytes/chip={v['hlo_bytes_per_chip']:.2e} "
            f"coll={v['collective_bytes']:.2e}B")
    return out
