"""Hymba-1.5B [arXiv:2411.13676] — hybrid: parallel attention + Mamba heads.

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba fuses attention and SSM head outputs (per-branch RMSNorm, averaged) in every
block, uses 128 learned meta tokens (attention sinks) and sliding-window attention
=> ``long_500k`` runs with O(sink+window) KV plus O(1) SSM state.
"""
from repro.configs.base import AdapterConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    pattern=(("hymba", 1),),
    rope=True,
    sliding_window=1024,                      # Hymba's SWA layers
    ssm=SSMConfig(state_size=16, conv_width=4, dt_rank=48),
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="arXiv:2411.13676",
))
