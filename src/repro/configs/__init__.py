"""Architecture registry — importing this package registers every config."""
from repro.configs.base import (AdapterConfig, InputShape, INPUT_SHAPES,
                                ModelConfig, MoEConfig, SSMConfig, TrainConfig,
                                get_config, list_configs, register,
                                shape_runnable)

from repro.configs import (  # noqa: F401  (registration side-effects)
    starcoder2_7b,
    stablelm_3b,
    moonshot_v1_16b_a3b,
    seamless_m4t_large_v2,
    hymba_1p5b,
    qwen2p5_3b,
    llama3p2_vision_11b,
    rwkv6_7b,
    olmoe_1b_7b,
    llama4_maverick_400b_a17b,
    mbert_squad,
)

ASSIGNED = [
    "starcoder2-7b", "stablelm-3b", "moonshot-v1-16b-a3b",
    "seamless-m4t-large-v2", "hymba-1.5b", "qwen2.5-3b",
    "llama-3.2-vision-11b", "rwkv6-7b", "olmoe-1b-7b",
    "llama4-maverick-400b-a17b",
]
