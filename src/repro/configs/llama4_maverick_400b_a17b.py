"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family] — MoE.

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Like the real Maverick, MoE layers alternate with dense layers
(interleave step 2 => pattern [dense, moe] x 24) and each MoE layer carries a
shared expert next to the 128 routed top-1 experts ("early fusion" MoE).
Chunked attention is realized as sliding-window 8192 => ``long_500k`` runs.
"""
from repro.configs.base import AdapterConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    pattern=(("dense", 1), ("moe", 1)), repeats=24,
    rope=True, rope_theta=5e5,
    sliding_window=8192,                      # iRoPE chunked attention analogue
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, capacity_factor=1.25),
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
