"""Configuration system for the RingAda reproduction framework.

Every architecture in the public-pool assignment is expressed as a
:class:`ModelConfig`. A config fully determines:

  * the layer pattern (which block kinds repeat, how often),
  * attention/MoE/SSM hyper-parameters,
  * the adapter (PEFT) insertion (the paper's technique),
  * which input shapes are runnable (``long_500k`` needs sub-quadratic attention).

Configs are plain frozen dataclasses registered under an ``--arch <id>`` name via
:func:`register`. ``repro.configs`` imports every per-arch module so the registry is
always populated after ``import repro.configs``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
# dense   : GQA self-attention + dense FFN
# moe     : GQA self-attention + mixture-of-experts FFN
# rwkv    : RWKV-6 time-mix + channel-mix (attention-free)
# hymba   : parallel attention + Mamba(SSM) heads sharing one residual, + FFN
# cross   : self-attention + cross-attention (encoder memory) + dense FFN
BLOCK_KINDS = ("dense", "moe", "rwkv", "hymba", "cross")


@dataclass(frozen=True)
class AdapterConfig:
    """Serial adapter (Houlsby / MAD-X style), the paper's trainable module."""

    bottleneck: int = 64          # m — bottleneck dimension
    activation: str = "gelu"      # σ(·)
    # Zero-init of W_up makes a frozen (never-trained) adapter an exact identity,
    # which is how RingAda "deactivates" bottom-layer adapters.
    zero_init_up: bool = True


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 8
    d_expert: int = 1024          # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight
    router_z_weight: float = 1e-3
    # FSDP-shard expert weights over the data axes (required at 400B scale);
    # small-expert MoEs turn this off to kill the per-layer all-gathers
    # (EXPERIMENTS.md §Perf, collective-bound iteration).
    fsdp_experts: bool = True


@dataclass(frozen=True)
class SSMConfig:
    """Covers both RWKV-6 and Mamba-style (hymba) recurrences."""

    state_size: int = 16          # mamba N; rwkv uses head_dim x head_dim state
    head_dim: int = 64            # rwkv head size
    dt_rank: int = 64             # mamba Δ low-rank
    conv_width: int = 4           # mamba local conv
    decay_lora: int = 64          # rwkv6 data-dependent decay LoRA dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    # ----- backbone dimensions -----
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # ----- layer pattern -----
    # pattern entries: (block_kind, count); whole pattern repeats `repeats` times,
    # n_layers == repeats * sum(counts).
    pattern: Tuple[Tuple[str, int], ...] = (("dense", 1),)
    repeats: Optional[int] = None    # default n_layers // pattern length
    # ----- attention details -----
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None     # tokens; None = full attention
    # ----- sub-configs -----
    adapter: AdapterConfig = field(default_factory=AdapterConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # ----- encoder-decoder (audio) -----
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_is_causal: bool = False
    # ----- VLM / audio stubbed frontends -----
    n_frontend_tokens: int = 0       # image patches / audio frames supplied pre-embedded
    frontend: Optional[str] = None   # "vision" | "audio" | None
    # ----- head -----
    head_out: Optional[int] = None   # None => LM head (vocab); e.g. 2 = QA span
    vocab_pad_to: int = 256          # pad embed/head vocab dim for sharding
    # ----- serving -----
    kv_quant: bool = False           # int8 KV cache (+per-row bf16 scales)
    # ----- misc -----
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # FFN activation (gelu for BERT-era)
    glu: bool = True                 # gated FFN (SwiGLU); False = classic 2-matrix FFN
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_seq_len: int = 524_288
    source: str = ""                 # citation from the assignment table

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        per_rep = sum(c for _, c in self.pattern)
        if self.repeats is None:
            assert self.n_layers % per_rep == 0, (self.name, self.n_layers, per_rep)
            object.__setattr__(self, "repeats", self.n_layers // per_rep)
        assert self.repeats * per_rep == self.n_layers, (
            f"{self.name}: pattern {self.pattern} x {self.repeats} != {self.n_layers} layers")
        for kind, _ in self.pattern:
            assert kind in BLOCK_KINDS, kind
        if any(k == "moe" for k, _ in self.pattern):
            assert self.moe is not None, f"{self.name}: moe pattern without MoEConfig"
        if any(k in ("rwkv", "hymba") for k, _ in self.pattern):
            assert self.ssm is not None, f"{self.name}: ssm pattern without SSMConfig"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab_size // p) * p

    @property
    def out_dim(self) -> int:
        """Width of the head output (padded for LM heads; see models.transformer.head
        which biases pad logits to -inf)."""
        return self.head_out or self.padded_vocab

    @property
    def layers_per_repeat(self) -> int:
        return sum(c for _, c in self.pattern)

    @property
    def attention_free(self) -> bool:
        return all(k == "rwkv" for k, _ in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts with O(1)/O(window) state."""
        kinds = {k for k, _ in self.pattern}
        if kinds <= {"rwkv"}:
            return True
        if "hymba" in kinds:
            return True
        return self.sliding_window is not None

    @property
    def kv_cacheable(self) -> bool:
        return any(k in ("dense", "moe", "hymba", "cross") for k, _ in self.pattern)

    def param_count(self) -> int:
        """Exact backbone parameter count (matches models.params tree)."""
        from repro.models import params as P  # local import to avoid cycle

        return P.count_params(P.param_defs(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts instead of all)."""
        from repro.models import params as P

        return P.count_params(P.param_defs(self), active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant used by CPU smoke tests (<=2 repeats, d<=512)."""
        small: Dict = dict(
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else self.n_kv_heads,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64,
            max_seq_len=4096,
        )
        per_rep = self.layers_per_repeat
        reps = 1 if per_rep > 1 else 2
        small["repeats"] = reps
        small["n_layers"] = reps * per_rep
        if self.moe is not None:
            small["moe"] = replace(self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                                   d_expert=128)
        if self.ssm is not None:
            small["ssm"] = replace(self.ssm, state_size=min(self.ssm.state_size, 8),
                                   head_dim=32, dt_rank=16, decay_lora=16)
        if self.enc_dec:
            small["n_enc_layers"] = 2
        if self.n_frontend_tokens:
            small["n_frontend_tokens"] = 16
        if self.sliding_window:
            small["sliding_window"] = 128
        small["adapter"] = replace(self.adapter, bottleneck=16)
        if self.n_kv_heads == self.n_heads:
            small["n_kv_heads"] = small["n_heads"]
        small.update(overrides)
        return replace(self, **small)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_runnable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (cfg, shape) a runnable combination? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full quadratic attention with an unbounded KV cache; no "
                       "sliding-window/SSM variant for this arch (see DESIGN.md §5)")
    return True, ""


# ---------------------------------------------------------------------------
# Training setup (the paper's Algorithm 1 knobs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    warmup_steps: int = 20
    batch_size: int = 8
    seq_len: int = 128
    steps: int = 200
    # --- RingAda schedule (Algorithm 1) ---
    initial_unfreeze_depth: int = 1   # d: head + top-most adapter
    unfreeze_interval: int = 40       # k: unfreeze one more adapter every k steps
    max_unfreeze_depth: Optional[int] = None   # default n_layers
    local_iterations: int = 1         # I per initiator
    # --- pipeline ---
    n_stages: int = 4
    n_microbatches: int = 8
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch id {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populate registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
