"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision] — VLM decoder.

Assigned: 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Cross-attention image layers every 5th block (8 of 40), realized as the layer
pattern [dense x4, cross x1] x 8. The ViT vision tower + projector is a stub per
the carve-out: ``input_specs`` supplies projected patch embeddings [B, T_img, d].
Gated cross-attention (tanh gate, zero-init) matches the real model.
Full attention => ``long_500k`` skipped.
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    pattern=(("dense", 4), ("cross", 1)), repeats=8,
    n_frontend_tokens=1024, frontend="vision",
    rope=True, rope_theta=5e5,
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
