"""The paper's own evaluation model: mBERT (bert-base-multilingual) + SQuAD QA.

12L d_model=768 12H d_ff=3072 vocab=119547, learned positions, post-LN-era
LayerNorm + GELU, MAD-X style adapters (bottleneck 48). Used by the Table-I /
Fig-3 reproduction benchmarks (benchmarks/table1_sim.py, benchmarks/convergence.py).
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mbert-squad",
    family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=119547,
    pattern=(("dense", 1),),
    rope=False,                      # learned positional embeddings
    norm="layernorm",
    glu=False, activation="gelu",
    head_out=2,                      # SQuAD span head (start/end logits)
    adapter=AdapterConfig(bottleneck=48),
    max_seq_len=512,
    source="arXiv:1810.04805 + arXiv:1606.05250 (paper's own eval setup)",
))
