"""OLMoE-1B-7B [arXiv:2409.02060] — MoE decoder, 64 experts top-8.

Assigned: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8.
Fine-grained experts (d_expert=1024). Full attention => ``long_500k`` skipped.
"""
from repro.configs.base import AdapterConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    pattern=(("moe", 1),),
    rope=True,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="arXiv:2409.02060",
))
