"""RWKV-6 "Finch" 7B [arXiv:2404.05892] — attention-free RNN with data-dependent decay.

Assigned: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
Head size 64 (64 heads), data-dependent token-shift (ddlerp) and decay LoRA.
O(1) recurrent state => ``long_500k`` runs natively. Adapters attach after each
block's channel-mix — the paper's technique is block-structural, so it applies
unchanged to attention-free architectures (DESIGN.md §5).
"""
from repro.configs.base import AdapterConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    pattern=(("rwkv", 1),),
    rope=False,                # RWKV has no positional encoding beyond recurrence
    ssm=SSMConfig(head_dim=64, decay_lora=64),
    glu=False, activation="relu",   # channel-mix uses squared ReLU internally
    adapter=AdapterConfig(bottleneck=64),
    source="arXiv:2404.05892",
))
