"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family] — dense MHA decoder.

Assigned: 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
Full attention (kv == heads) => ``long_500k`` is skipped (see DESIGN.md §5).
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    pattern=(("dense", 1),),
    rope=True,
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="hf:stabilityai/stablelm-2-1_6b",
))
