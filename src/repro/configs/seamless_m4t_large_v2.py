"""SeamlessM4T-large-v2 [arXiv:2308.11596] — encoder-decoder, audio frontend stubbed.

Assigned: 24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
Per the carve-out, the mel-spectrogram + conformer feature extractor is a stub:
``input_specs`` supplies pre-computed frame embeddings [B, T_a, d_model]. "24L" is
read per stack (24 encoder + 24 decoder, matching the real M4T-v2 text stacks).
Full attention => ``long_500k`` skipped.
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    pattern=(("cross", 1),),                 # decoder layers cross-attend encoder
    enc_dec=True, n_enc_layers=24,
    n_frontend_tokens=4096, frontend="audio",
    rope=True,
    glu=False, activation="relu",            # m4t uses ReLU FFNs
    norm="layernorm",
    adapter=AdapterConfig(bottleneck=64),
    source="arXiv:2308.11596",
))
