"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — MoE decoder.

Assigned: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
DeepSeek-style fine-grained experts (d_expert=1408) + one always-on shared expert.
Full attention => ``long_500k`` skipped.
"""
from repro.configs.base import AdapterConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    pattern=(("moe", 1),),
    rope=True,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408),
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="hf:moonshotai/Moonlight-16B-A3B",
))
