"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family] — dense GQA decoder with QKV bias.

Assigned: 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
Qwen2.5 supports sliding-window attention (32k); we enable it so ``long_500k``
runs with an O(window) cache.
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936,
    pattern=(("dense", 1),),
    rope=True, rope_theta=1e6,
    qkv_bias=True,
    sliding_window=32768,
    glu=True, activation="silu",
    adapter=AdapterConfig(bottleneck=64),
    source="hf:Qwen/Qwen2.5-0.5B",
))
