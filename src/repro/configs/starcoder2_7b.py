"""StarCoder2-7B [arXiv:2402.19173] — dense GQA decoder with RoPE.

Assigned: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
The real model uses sliding-window attention (4096), which we keep — it is what
makes the ``long_500k`` decode shape runnable for this arch (O(window) cache).
"""
from repro.configs.base import AdapterConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    pattern=(("dense", 1),),
    rope=True, rope_theta=1e5,
    sliding_window=4096,
    glu=False, activation="gelu",          # starcoder2 uses a plain GELU MLP
    adapter=AdapterConfig(bottleneck=64),
    source="arXiv:2402.19173",
))
