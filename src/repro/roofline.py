"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOPs_per_chip
    memory term     = HLO_bytes / HBM_bw_per_chip
    collective term = collective_bytes_per_chip / ICI_link_bw

Sources: ``compiled.cost_analysis()`` (XLA reports *per-device* flops/bytes for an
SPMD module) and the optimized HLO text for collective operand bytes —
``all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute``,
each multiplied by the trip count of any enclosing while loop (collectives inside
a scan run once per iteration).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Known XLA-CPU cost-model quirk (documented in EXPERIMENTS.md): when a program
contains several structurally-similar while loops (the RingAda split-scan train
step), ``cost_analysis`` attributes full-depth trip counts to each loop. Baseline
dry-runs use single-scan programs (boundary=0 / serve steps) which are unaffected;
``analytic_flops`` is reported alongside for cross-checking.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op, x enclosing-loop trip counts."""
    # 1. map computation name -> body text, find while trip counts
    comp_of_line: List[Tuple[str, str]] = []
    cur = "__entry__"
    trip: Dict[str, float] = {}
    calls: List[Tuple[str, str, float]] = []   # (parent_comp, body_comp, trips)
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", ls)
        if m and ls.endswith("{"):
            cur = m.group(1)
            continue
        if " while(" in ls or ls.startswith("while("):
            bm = re.search(r"body=%?([\w.\-]+)", ls)
            tm = re.search(r'known_trip_count[^\d]*(\d+)', ls)
            if bm:
                calls.append((cur, bm.group(1), float(tm.group(1)) if tm else 1.0))
        cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ls)
        if cm:
            calls.append((cur, cm.group(1), 1.0))
        comp_of_line.append((cur, ls))

    # multiplier per computation (product of trip counts down the call chain)
    mult: Dict[str, float] = {"__entry__": 1.0}
    # entry computation: the one annotated ENTRY
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry:
        mult[entry] = 1.0
    changed = True
    it = 0
    while changed and it < 50:
        changed, it = False, it + 1
        for parent, body, t in calls:
            pm = mult.get(parent)
            if pm is None:
                continue
            new = pm * t
            if mult.get(body, 0) < new:
                mult[body] = new
                changed = True

    out = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    for comp, ls in comp_of_line:
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start|-done)?\(", ls) and "=" in ls:
                if f"{kind}-done" in ls:
                    continue   # counted at -start
                # operand shapes: everything after the op name's '('
                try:
                    rhs = ls.split(f"{kind}", 1)[1]
                except IndexError:
                    continue
                shapes = _SHAPE_RE.findall(rhs)
                nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
                m = mult.get(comp, 1.0)
                out[kind] += nbytes * m
                out["total"] += nbytes * m
                break
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs (cross-check for the XLA cost model; also gives MODEL_FLOPS)
# ---------------------------------------------------------------------------


def _block_fwd_flops_per_token(cfg: ModelConfig, kind: str, ctx_len: float,
                               mem_len: int = 0) -> float:
    """Forward FLOPs per token for one block of ``kind``.

    ctx_len: average attended context length (S/2 causal, window for SWA,
    cache length for decode).
    """
    D, H, K, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                      cfg.d_ff)
    m = cfg.adapter.bottleneck
    f = 4.0 * D * m                                   # the serial adapter
    ffn = (6.0 if cfg.glu else 4.0) * D * F
    if kind in ("dense", "moe", "cross", "hymba"):
        f += 2.0 * D * (H + 2 * K) * hd + 2.0 * D * H * hd   # qkvo proj
        f += 4.0 * H * hd * ctx_len                          # scores + AV
    if kind in ("dense", "cross"):
        f += ffn
    if kind == "cross":
        f += 2.0 * D * H * hd + 2.0 * D * H * hd             # q + out proj
        f += 4.0 * H * hd * mem_len                          # attend memory
        # memory kv projections amortize over the sequence; count per token
        f += 4.0 * D * K * hd
    if kind == "moe":
        mo = cfg.moe
        f += 2.0 * D * mo.n_experts                          # router
        f += mo.top_k * (6.0 * D * mo.d_expert) * mo.capacity_factor
        f += 6.0 * D * F                                     # shared expert
    if kind == "hymba":
        di = H * hd
        N = cfg.ssm.state_size
        f += 2.0 * D * di + 2.0 * cfg.ssm.conv_width * di
        f += 2.0 * di * (cfg.ssm.dt_rank + 2 * N) + 2.0 * cfg.ssm.dt_rank * di
        f += 6.0 * di * N                                    # state update + C
        f += ffn
    if kind == "rwkv":
        f += 6.0 * 2.0 * D * D                               # r,k,v,g,o,r_c
        f += 2.0 * D * cfg.ssm.decay_lora * 2                # decay lora
        f += 4.0 * D * cfg.ssm.head_dim                      # wkv state math
        f += 4.0 * D * F                                     # channel mix
    return f


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Whole-program FLOPs (global, all chips) for the baseline step.

    train:   fwd + remat re-fwd + dgrad (~fwd) over all layers  (~3x fwd)
             + adapter/head wgrads (small, counted)
    prefill: fwd
    decode:  fwd at ctx = cache length, tokens = B
    """
    S = shape.seq_len
    B = shape.global_batch
    from repro.models import kvcache

    if shape.kind == "decode":
        tokens = float(B)
        ctx = kvcache.cache_len(cfg, S)
    else:
        tokens = float(B) * S
        ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S / 2.0

    mem_len = cfg.n_frontend_tokens
    per_tok = sum(_block_fwd_flops_per_token(cfg, kind, ctx, mem_len) * count
                  for kind, count in cfg.pattern) * cfg.repeats
    head_f = 2.0 * cfg.d_model * cfg.out_dim
    fwd = tokens * (per_tok + head_f)
    if cfg.enc_dec and mem_len:
        enc_tok = float(B) * mem_len * (1 if shape.kind != "decode" else 0)
        fwd += enc_tok * cfg.n_enc_layers * _block_fwd_flops_per_token(
            cfg, "dense", mem_len / 2.0)
    if shape.kind != "train":
        return fwd
    # backward: remat re-forward + dgrad (~= fwd each) + trainable wgrads
    wgrad = tokens * (4.0 * cfg.d_model * cfg.adapter.bottleneck * cfg.n_layers
                      + 2.0 * cfg.d_model * cfg.out_dim)
    return 3.0 * fwd + wgrad


def model_flops(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    from repro.models import params as prm

    n_total = prm.count_params(prm.param_defs(cfg))
    n_active = prm.count_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return {"model_flops": 6.0 * n_active * tokens,
                "n_params": n_total, "n_active": n_active, "tokens": tokens}
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return {"model_flops": 2.0 * n_active * tokens,
                "n_params": n_total, "n_active": n_active, "tokens": tokens}
    tokens = shape.global_batch          # one new token per sequence
    return {"model_flops": 2.0 * n_active * tokens,
            "n_params": n_total, "n_active": n_active, "tokens": tokens}


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float
    n_params: float
    n_active: float
    analytic_flops_total: float = 0.0

    @property
    def compute_s(self) -> float:
        """XLA's CPU cost model drops trip counts for some SPMD-partitioned
        scans (documented in EXPERIMENTS.md), so the compute term uses the
        larger of the XLA estimate and the analytic per-chip FLOPs."""
        per_chip = max(self.hlo_flops_per_chip,
                       self.analytic_flops_total / self.chips)
        return per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = max(self.hlo_flops_per_chip * self.chips,
                    self.analytic_flops_total)
        return self.model_flops / total if total else float("nan")

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        t = self.step_time_s
        return (self.model_flops / (self.chips * PEAK_FLOPS * t)) if t else 0.0

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_ratio=self.useful_ratio, step_time_s=self.step_time_s,
                 mfu=self.mfu)
        return d


def build(arch: str, shape: InputShape, mesh_name: str, chips: int,
          cost: Dict[str, float], coll: Dict[str, float],
          mf: Dict[str, float], analytic: float = 0.0) -> Roofline:
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=float(cost.get("flops", 0.0)),
        hlo_bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_chip=float(coll.get("total", 0.0)) / chips,
        model_flops=mf["model_flops"], n_params=mf["n_params"],
        n_active=mf["n_active"], analytic_flops_total=analytic)
