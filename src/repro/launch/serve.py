"""Batched serving driver: prefill + decode with KV caches.

Implements a simple synchronous continuous-batching server loop: requests are
padded into fixed batch slots, prefilled once, then decoded step-by-step; finished
slots are refilled from the queue. Serves any registered arch (reduced variants on
CPU).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import params as prm
from repro.models import transformer as tfm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [L] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class BatchServer:
    """Fixed-slot synchronous batcher (one shared KV cache, per-slot positions)."""

    def __init__(self, cfg, params, *, slots: int, horizon: int,
                 impl: str = "jnp"):
        self.cfg, self.params = cfg, params
        self.slots, self.horizon = slots, horizon
        mem = None
        if cfg.frontend or cfg.enc_dec:
            mem = jnp.zeros((1, cfg.n_frontend_tokens or 16, cfg.d_model),
                            jnp.bfloat16)
        self._memory = mem
        self.prefill = jax.jit(
            lambda p, t, m=None: tfm.prefill(p, t, cfg, memory=m,
                                             seq_len=horizon, impl=impl))
        self.decode = jax.jit(
            lambda p, t, c: tfm.decode_step(p, t, c, cfg, impl=impl),
            donate_argnums=(2,))

    def run(self, requests: List[Request], log=print) -> Dict[int, List[int]]:
        queue = list(requests)
        t0 = time.time()
        decoded_tokens = 0
        results: Dict[int, List[int]] = {}
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots:]
            L = max(len(r.prompt) for r in batch)
            toks = np.zeros((len(batch), L), np.int32)
            for i, r in enumerate(batch):
                toks[i, L - len(r.prompt):] = r.prompt     # left-pad
            mem = (jnp.broadcast_to(self._memory,
                                    (len(batch),) + self._memory.shape[1:])
                   if self._memory is not None else None)
            args = (self.params, jnp.asarray(toks)) + (
                (mem,) if mem is not None else ())
            logits, cache = self.prefill(*args)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            max_new = max(r.max_new for r in batch)
            outs = [cur]
            for _ in range(max_new - 1):
                logits, cache = self.decode(self.params, cur, cache)
                cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                outs.append(cur)
                decoded_tokens += len(batch)
            gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
            for i, r in enumerate(batch):
                results[r.rid] = gen[i, : r.max_new].tolist()
        dt = time.time() - t0
        log(f"served {len(requests)} requests, "
            f"{decoded_tokens} decode steps in {dt:.2f}s "
            f"({decoded_tokens / max(dt, 1e-9):.1f} tok/s)")
        return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, args.prompt_len + 1)
                                    ).astype(np.int32), args.max_new)
            for i in range(args.requests)]
    server = BatchServer(cfg, params, slots=args.slots,
                         horizon=args.prompt_len + args.max_new + 8)
    results = server.run(reqs)
    print({k: v[:8] for k, v in list(results.items())[:4]})


if __name__ == "__main__":
    main()
