"""Batched serving driver: prefill + decode with KV caches.

Implements a simple synchronous continuous-batching server loop: requests are
padded into fixed batch slots, prefilled once, then decoded step-by-step; finished
slots are refilled from the queue. Serves any registered arch (reduced variants on
CPU).

Multi-tenant adapter hot-swap (S-LoRA style): with ``--adapter-store DIR``
pointing at an :class:`repro.api.tenants.AdapterStore`, each request may carry
a tenant id (a store entry name).  ONE shared trunk stays resident; the
:class:`AdapterRegistry` grafts each tenant's trained adapter+head bundle into
the base tree (same shapes, so the jitted prefill/decode executables are
reused across tenants — zero recompiles on swap), the batcher groups each
batch by tenant, and the registry re-checks store mtimes between batches: a
bundle a training session just ``save_to``'d is servable on the very next
batch, no restart.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 8 --max-new 16 [--adapter-store ckpt/adapters]
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import params as prm
from repro.models import transformer as tfm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [L] int32
    max_new: int
    tenant: Optional[str] = None       # AdapterStore entry name; None = trunk
    out: List[int] = field(default_factory=list)
    done: bool = False


class AdapterRegistry:
    """Per-tenant merged param trees over one shared trunk.

    ``params_for(tenant)`` grafts the tenant's ``{"adapter", "head"}`` bundle
    from the store into the base canonical tree — the graft only swaps
    leaves, never shapes, so every tenant runs through the SAME jitted
    executables.  ``refresh()`` reloads any entry whose payload mtime moved
    (the hot-swap hook: a freshly trained bundle is picked up between
    batches) and returns the names it swapped in.
    """

    def __init__(self, base_params: Dict[str, Any], store):
        self.base = base_params
        self.store = store
        self._like = {"adapter": base_params["blocks"][0]["adapter"],
                      "head": base_params["head"]}
        self._merged: Dict[str, Dict[str, Any]] = {}
        self._mtimes: Dict[str, float] = {}

    def refresh(self) -> List[str]:
        swapped = []
        for name in self.store.names():
            mt = self.store.mtime(name)
            if self._mtimes.get(name) == mt:
                continue
            bundle, _ = self.store.get(name, self._like)
            entry = {**self.base["blocks"][0], "adapter": bundle["adapter"]}
            self._merged[name] = {**self.base, "head": bundle["head"],
                                  "blocks": (entry,)}
            self._mtimes[name] = mt
            swapped.append(name)
        return swapped

    def tenants(self) -> List[str]:
        return sorted(self._merged)

    def params_for(self, tenant: Optional[str]) -> Dict[str, Any]:
        if tenant is None:
            return self.base
        if tenant not in self._merged:
            self.refresh()
        if tenant not in self._merged:
            raise KeyError(
                f"unknown tenant {tenant!r}: store has {self.tenants()}")
        return self._merged[tenant]


class BatchServer:
    """Fixed-slot synchronous batcher (one shared KV cache, per-slot positions).

    With a ``registry`` each batch is tenant-homogeneous: the queue is
    consumed in arrival order, but one batch only packs requests that share
    the head request's tenant (the trunk counts as a tenant of its own), and
    the registry's mtime watch runs between batches so hot-swapped adapters
    take effect on the next batch.
    """

    def __init__(self, cfg, params, *, slots: int, horizon: int,
                 impl: str = "jnp", registry: Optional[AdapterRegistry] = None):
        self.cfg, self.params = cfg, params
        self.registry = registry
        self.slots, self.horizon = slots, horizon
        mem = None
        if cfg.frontend or cfg.enc_dec:
            mem = jnp.zeros((1, cfg.n_frontend_tokens or 16, cfg.d_model),
                            jnp.bfloat16)
        self._memory = mem
        self.prefill = jax.jit(
            lambda p, t, m=None: tfm.prefill(p, t, cfg, memory=m,
                                             seq_len=horizon, impl=impl))
        self.decode = jax.jit(
            lambda p, t, c: tfm.decode_step(p, t, c, cfg, impl=impl),
            donate_argnums=(2,))

    def run(self, requests: List[Request], log=print) -> Dict[int, List[int]]:
        queue = list(requests)
        t0 = time.time()
        decoded_tokens = 0
        results: Dict[int, List[int]] = {}
        while queue:
            if self.registry is not None:
                for name in self.registry.refresh():    # hot-swap point
                    log(f"adapter hot-swap: reloaded {name!r}")
                tenant = queue[0].tenant
                batch = [r for r in queue
                         if r.tenant == tenant][: self.slots]
                taken = {id(r) for r in batch}
                queue = [r for r in queue if id(r) not in taken]
                params = self.registry.params_for(tenant)
            else:
                batch = queue[: self.slots]
                queue = queue[self.slots:]
                params = self.params
            L = max(len(r.prompt) for r in batch)
            toks = np.zeros((len(batch), L), np.int32)
            for i, r in enumerate(batch):
                toks[i, L - len(r.prompt):] = r.prompt     # left-pad
            mem = (jnp.broadcast_to(self._memory,
                                    (len(batch),) + self._memory.shape[1:])
                   if self._memory is not None else None)
            args = (params, jnp.asarray(toks)) + (
                (mem,) if mem is not None else ())
            logits, cache = self.prefill(*args)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            max_new = max(r.max_new for r in batch)
            outs = [cur]
            for _ in range(max_new - 1):
                logits, cache = self.decode(params, cur, cache)
                cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                outs.append(cur)
                decoded_tokens += len(batch)
            gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
            for i, r in enumerate(batch):
                results[r.rid] = gen[i, : r.max_new].tolist()
        dt = time.time() - t0
        log(f"served {len(requests)} requests, "
            f"{decoded_tokens} decode steps in {dt:.2f}s "
            f"({decoded_tokens / max(dt, 1e-9):.1f} tok/s)")
        return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--layers", type=int, default=None,
                    help="override the block count (applied after --reduced; "
                         "match the training run when serving its adapters)")
    ap.add_argument("--adapter-store", default=None,
                    help="AdapterStore directory of trained per-tenant "
                         "bundles; requests round-robin over the entries "
                         "(plus the bare trunk) and each batch serves its "
                         "tenant's grafted params — hot-swapped on mtime "
                         "change, no restart")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=args.layers,
                                  repeats=args.layers // cfg.layers_per_repeat)
    params = prm.materialize(prm.param_defs(cfg), jax.random.key(0), cfg.dtype)
    registry = None
    tenant_cycle: List[Optional[str]] = [None]
    if args.adapter_store:
        from repro.api.tenants import AdapterStore

        registry = AdapterRegistry(params, AdapterStore(args.adapter_store))
        names = registry.refresh()
        print(f"adapter store: serving trunk + {len(names)} tenants {names}")
        tenant_cycle = [None] + list(names)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, args.prompt_len + 1)
                                    ).astype(np.int32), args.max_new,
                    tenant=tenant_cycle[i % len(tenant_cycle)])
            for i in range(args.requests)]
    server = BatchServer(cfg, params, slots=args.slots,
                         horizon=args.prompt_len + args.max_new + 8,
                         registry=registry)
    results = server.run(reqs)
    print({k: v[:8] for k, v in list(results.items())[:4]})


if __name__ == "__main__":
    main()
