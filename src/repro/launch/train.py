"""Training driver: RingAda fine-tuning with scheduled layer unfreezing.

Two execution modes:
  * ``--mode pjit`` (default): single- or multi-device data/tensor-parallel
    training with the static unfreeze boundary (staged re-jit per depth change).
  * ``--mode ring``: shard_map ring pipeline across ``--stages`` devices with
    rotating initiators (needs >= stages local devices, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch mbert-squad --steps 120 \
        --reduced --mode pjit
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.core.unfreeze import UnfreezeSchedule, boundary_schedule
from repro.data.pipeline import Batcher, RingBatcher, make_client_datasets, merged
from repro.models import params as prm
from repro.optim import adamw
from repro.checkpoint import checkpoint as ckpt


def train_pjit(cfg, tc: TrainConfig, *, steps: int, log_every: int = 10,
               scheme: str = "ringada", impl: str = "jnp",
               save_path: Optional[str] = None, log=print) -> Dict[str, Any]:
    """Single-process training loop with the paper's unfreeze schedule.

    scheme: 'ringada' (scheduled unfreezing) | 'all_hot' (PipeAdapter/Single-style
    baseline: every adapter trainable from step 0).
    """
    key = jax.random.key(tc.seed)
    params = prm.materialize(prm.param_defs(cfg), key, cfg.dtype)
    opt_state = adamw.init(training.full_trainable(params))
    qa = cfg.head_out == 2
    ds = merged(make_client_datasets(4, vocab=cfg.vocab_size,
                                     n_per_client=256, seq=tc.seq_len,
                                     seed=tc.seed, kind="qa" if qa else "lm"))
    batcher = Batcher(ds, tc.batch_size, seed=tc.seed)

    sched = UnfreezeSchedule.from_train_config(tc)
    if scheme == "all_hot":
        segs = [(0, steps, 0)]
    else:
        segs = boundary_schedule(cfg, sched, steps)

    history = []
    t0 = time.time()
    step_fns: Dict[int, Any] = {}
    for (s0, s1, boundary) in segs:
        if boundary not in step_fns:
            mk = (training.make_qa_train_step if qa
                  else training.make_train_step)
            step_fns[boundary] = jax.jit(mk(cfg, tc, boundary, impl=impl),
                                         donate_argnums=(0, 1))
        fn = step_fns[boundary]
        for step in range(s0, s1):
            batch = batcher.next()
            params, opt_state, metrics = fn(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, boundary=boundary,
                         depth=cfg.repeats - boundary,
                         wall_s=round(time.time() - t0, 2))
                history.append(m)
                acc = m.get("accuracy", m.get("f1", 0.0))
                log(f"step {step:5d} b={boundary:2d} "
                    f"loss={m['loss']:.4f} acc/f1={acc:.3f} "
                    f"({m['wall_s']}s)")
    if save_path:
        ckpt.save(save_path, params, step=steps, adapters_only=True)
    return {"history": history, "params": params, "opt_state": opt_state,
            "wall_s": time.time() - t0}


def train_ring(cfg, tc: TrainConfig, *, rounds: int, n_stages: int,
               log_every: int = 1, log=print) -> Dict[str, Any]:
    from repro.core.ring import RingTrainer
    from repro.launch.mesh import make_ring_mesh, require_devices

    require_devices(n_stages)
    mesh = make_ring_mesh(n_stages)
    key = jax.random.key(tc.seed)
    params = prm.materialize(prm.param_defs(cfg), key, cfg.dtype)
    trainer = RingTrainer(cfg, tc, mesh, params, n_stages, tc.n_microbatches)
    clients = make_client_datasets(n_stages, vocab=cfg.vocab_size,
                                   n_per_client=128, seq=tc.seq_len,
                                   seed=tc.seed)
    rb = RingBatcher(clients, tc.n_microbatches, tc.batch_size, seed=tc.seed)

    history = []
    t0 = time.time()
    with jax.set_mesh(mesh):
        for r in range(rounds):
            tokens, labels = rb.next()
            m = trainer.round(tokens, labels)
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            if r % log_every == 0:
                log(f"round {r:4d} loss={m['loss']:.4f} "
                    f"boundary={m['boundary']} ({m['wall_s']}s)")
    return {"history": history, "trainer": trainer,
            "wall_s": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mbert-squad")
    ap.add_argument("--mode", choices=["pjit", "ring"], default="pjit")
    ap.add_argument("--scheme", choices=["ringada", "all_hot"],
                    default="ringada")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--unfreeze-interval", type=int, default=40)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                     learning_rate=args.lr, steps=args.steps,
                     unfreeze_interval=args.unfreeze_interval)
    if args.mode == "pjit":
        out = train_pjit(cfg, tc, steps=args.steps, scheme=args.scheme,
                         save_path=args.save)
    else:
        out = train_ring(cfg, tc, rounds=args.rounds, n_stages=args.stages)
    print(json.dumps(out["history"][-1], default=float))


if __name__ == "__main__":
    main()
