"""Training driver: a thin CLI shell over ``repro.api.RingSession``.

Every mode is a (backend, policy) pair on the one session facade:

  * ``--mode pjit`` (default): staged-recompile data/tensor-parallel training
    (``PjitBackend``); ``--scheme all_hot`` maps to the PipeAdapter-style
    baseline policy (every adapter trainable from step 0).
  * ``--mode ring``: shard_map ring pipeline across ``--stages`` devices
    (needs >= stages local devices, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
    ``--trainer fused`` (default) is the donated single-executable
    ``FusedBackend`` — with ``--slots-per-epoch`` it upgrades to the
    ``CachedBackend`` (frozen-trunk Phase-A skip); ``--trainer reference``
    is the unfused ``ReferenceBackend`` oracle.

``--policy plateau`` swaps the paper's k-step rule for adaptive
loss-plateau unfreezing in either mode.  ``--save``/``--resume`` round-trip
the full session state (params + Adam moments + policy + data cursor) in
BOTH modes via ``RingSession.save``/``restore``.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch mbert-squad --steps 120 \
        --reduced --mode pjit
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

from repro.api import (ExplicitPolicy, LoggingCallback, RingSession,
                       resolve_policy)
from repro.configs import TrainConfig, get_config


def train_pjit(cfg, tc: TrainConfig, *, steps: int, log_every: int = 10,
               scheme: str = "ringada", impl: str = "jnp",
               save_path: Optional[str] = None, resume: Optional[str] = None,
               policy: Any = None, log=print) -> Dict[str, Any]:
    """Single-process training with the paper's unfreeze schedule — a shell
    over ``RingSession`` with the pjit backend.

    scheme: 'ringada' (scheduled unfreezing) | 'all_hot' (PipeAdapter/Single-
    style baseline: every adapter trainable from step 0).

    Note (vs the pre-session loop): the returned history now carries EVERY
    step (host-synced in log_every batches, so async dispatch is unchanged),
    not just the logged ones, and ``step`` counts from 1 (the value AFTER the
    update) rather than 0.
    """
    if scheme not in ("ringada", "all_hot"):
        raise ValueError(f"scheme must be 'ringada' or 'all_hot', got {scheme!r}")
    if scheme == "all_hot":
        if policy not in (None, "interval"):
            raise ValueError("scheme='all_hot' fixes the policy (every "
                             "adapter hot from step 0) — drop --policy")
        policy = ExplicitPolicy((cfg.n_layers,))
    policy = resolve_policy(policy, tc)
    if resume:
        sess = RingSession.restore(resume, cfg, tc, backend="pjit",
                                   policy=policy, impl=impl, log=log)
    else:
        sess = RingSession.create(cfg, tc, backend="pjit", policy=policy,
                                  impl=impl, log=log)
    t0 = time.time()
    history = sess.run(steps, log_every=log_every,
                       callbacks=[LoggingCallback(log, every=log_every)])
    if save_path:
        sess.save(save_path)
    st = sess.backend.state()
    return {"history": history, "params": st["params"], "opt_state": st["opt"],
            "session": sess, "wall_s": time.time() - t0}


def train_ring(cfg, tc: TrainConfig, *, rounds: int, n_stages: int,
               log_every: int = 1, trainer: str = "fused",
               slots_per_epoch: Optional[int] = None,
               cache_capacity: Optional[int] = None,
               packed: bool = True, cache_dtype: str = "native",
               device_speeds: Optional[Any] = None,
               tenants: int = 1, adapter_store: Optional[str] = None,
               chaos: Any = (), elastic: bool = False,
               save_path: Optional[str] = None, resume: Optional[str] = None,
               policy: Any = None, log=print) -> Dict[str, Any]:
    """Ring-pipeline training across ``n_stages`` devices — a shell over
    ``RingSession`` with the matching ring backend.

    trainer='fused' (default): the donated single-executable round; with
    ``slots_per_epoch`` this becomes the cached backend (steady-state
    revisits of a (slot, boundary) key skip Phase A entirely; a boundary drop
    invalidates the cache).  trainer='reference': the unfused oracle.
    ``cache_capacity`` defaults to ``slots_per_epoch``; 0 disables the cache
    while keeping slotted batches.  ``packed=False`` reverts Phase A to the
    per-owner scan (the packed conveyor is on by default); ``cache_dtype``
    compresses cache entries ('bf16' halves, 'int8' quarters the bytes per
    entry — see ``core/actcache.py`` for the accuracy tradeoff).

    ``device_speeds`` (one relative speed per stage, ring order — the CLI's
    ``--device-speeds 1.0,0.5,2.0,1.0``) runs the paper's speed-weighted
    layer assignment: faster devices get proportionally larger contiguous
    block spans (Algorithm 1; the 4:5:2:3 example).  The resulting span
    layout is recorded in ``--save`` checkpoints and restored by
    ``--resume``.

    ``tenants=T > 1`` (fused/cached) trains T per-tenant adapter sets over
    one shared frozen trunk in a single joint conveyor; ``adapter_store``
    exports every tenant's adapters+moments as named ``AdapterStore``
    bundles (``tenant0``, ``tenant1``, ...) after the run — directly
    hot-servable by ``launch/serve.py --adapter-store``.

    ``chaos`` (the CLI's repeatable ``--chaos ROUND:EVENT:DEVICE[:FACTOR]``)
    injects churn events mid-run; ``elastic=True`` lets the ring absorb them
    live — a crash shrinks the ring to the survivors (checkpoint-free, see
    README "Fault tolerance"), a slowdown is picked up by the straggler
    detector and repartitioned away.  Without ``elastic``, a crash raises.
    """
    if trainer not in ("fused", "reference"):
        raise ValueError(f"trainer must be 'fused' or 'reference', "
                         f"got {trainer!r}")
    if tenants > 1 and trainer != "fused":
        raise ValueError("--tenants > 1 needs the fused executor "
                         "(--trainer fused)")
    if trainer == "reference":
        backend = "reference"
    else:
        cap = (cache_capacity if cache_capacity is not None
               else (slots_per_epoch or 0))
        backend = "cached" if (slots_per_epoch and cap) else "fused"
    if resume:
        if device_speeds is not None:
            raise ValueError(
                "--device-speeds cannot be combined with --resume: the span "
                "layout is part of the checkpointed state (stage-stacked "
                "Adam moments are laid out per span), so resume always "
                "restores the saved layout. To repartition, start a fresh "
                "run with the new speeds, or use RingExecutor.repartition "
                "programmatically.")
        # the checkpoint records backend/stages/slots/capacity/spans;
        # re-deriving them from (possibly omitted) CLI flags would silently
        # resume a slotted cached run as fused+streaming — a different data
        # sequence.
        # chaos rounds are relative to THIS run (the wrapper's round counter
        # starts at 0 on resume); elastic defaults to the checkpointed value
        kw: Dict[str, Any] = {}
        if chaos:
            kw["chaos"] = chaos
        if elastic:
            kw["elastic"] = True
        sess = RingSession.restore(resume, cfg, tc, policy=policy, log=log,
                                   **kw)
        if sess.backend.kind != "ring":
            raise ValueError(
                f"--resume checkpoint was saved by the "
                f"{sess.backend.name!r} backend; resume it with --mode pjit")
    else:
        sess = RingSession.create(cfg, tc, backend=backend, policy=policy,
                                  n_stages=n_stages,
                                  slots_per_epoch=slots_per_epoch,
                                  cache_capacity=cache_capacity,
                                  packed=packed, cache_dtype=cache_dtype,
                                  device_profiles=device_speeds,
                                  tenants=tenants, chaos=chaos,
                                  elastic=elastic, log=log)
        if device_speeds is not None:
            log(f"heterogeneous ring: speeds {list(device_speeds)} -> spans "
                f"{[list(sp) for sp in sess.backend.spans]}")
    t0 = time.time()
    history = sess.run(rounds, log_every=log_every,
                       callbacks=[LoggingCallback(log, every=log_every)])
    if save_path:
        sess.save(save_path)
    if adapter_store:
        from repro.api import AdapterStore

        store = AdapterStore(adapter_store)
        for group in sess.tenants:
            group.save_to(store, f"tenant{group.index}")
        log(f"exported {sess.n_tenants} adapter bundle(s) to {adapter_store}")
    return {"history": history, "trainer": sess.backend.driver,
            "session": sess, "wall_s": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mbert-squad")
    ap.add_argument("--mode", choices=["pjit", "ring"], default="pjit")
    ap.add_argument("--scheme", choices=["ringada", "all_hot"],
                    default="ringada")
    ap.add_argument("--policy", choices=["interval", "plateau"],
                    default="interval",
                    help="unfreeze policy: the paper's k-step rule, or "
                         "adaptive loss-plateau unfreezing")
    ap.add_argument("--trainer", choices=["fused", "reference"],
                    default="fused",
                    help="ring backend: fused RingExecutor or the unfused "
                         "RingTrainer oracle")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--layers", type=int, default=None,
                    help="override the block count (applied after --reduced; "
                         "must be a multiple of the arch's layers-per-repeat "
                         "— e.g. 14 runs the paper's 4:5:2:3 heterogeneous "
                         "example with --device-speeds)")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=8,
                    help="ring mode: microbatches in flight per round")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--unfreeze-interval", type=int, default=40)
    ap.add_argument("--slots-per-epoch", type=int, default=0,
                    help="ring mode: epoch-stable batch slots (the activation "
                         "cache's key space; e.g. 8 enables the Phase-A-skip "
                         "cache); 0 (default) = streaming random batches, "
                         "cache off — the pre-cache behavior")
    ap.add_argument("--cache-capacity", type=int, default=None,
                    help="ring mode: boundary-activation cache entries "
                         "(default: slots-per-epoch; 0 disables the cache)")
    ap.add_argument("--no-cache", action="store_true",
                    help="ring mode: disable the frozen-trunk activation "
                         "cache (use for streaming/non-repeating data)")
    ap.add_argument("--cache-dtype", choices=["native", "f32", "bf16", "int8"],
                    default="native",
                    help="ring mode: activation-cache storage precision — "
                         "'native' stores entries exactly as captured, "
                         "'bf16' halves and 'int8' (per-row scales) quarters "
                         "the bytes per entry, fitting 2-4x more slots in "
                         "the same --cache-capacity memory budget")
    ap.add_argument("--tenants", type=int, default=1,
                    help="ring mode (fused/cached): train this many "
                         "per-tenant adapter sets over ONE shared frozen "
                         "trunk in a single joint conveyor; per tenant the "
                         "result is bit-identical to an independent run")
    ap.add_argument("--adapter-store", default=None,
                    help="ring mode: export each tenant's trained adapters + "
                         "Adam moments to this AdapterStore directory "
                         "(entries tenant0, tenant1, ...) — servable by "
                         "launch/serve.py --adapter-store without a restart")
    ap.add_argument("--device-speeds", default=None,
                    help="ring mode: comma-separated relative compute speeds, "
                         "one per stage in ring order (e.g. "
                         "'1.0,0.5,2.0,1.0') — runs the paper's "
                         "speed-weighted layer assignment so faster devices "
                         "hold larger contiguous block spans (Algorithm 1); "
                         "default: balanced spans")
    ap.add_argument("--chaos", action="append", default=[],
                    metavar="ROUND:EVENT:DEVICE[:FACTOR]",
                    help="ring mode: inject a churn event (repeatable) — "
                         "EVENT in {crash, leave, slowdown, join}, ROUND is "
                         "when it fires (rounds before it run on the old "
                         "fleet), DEVICE is the ORIGINAL stage index, FACTOR "
                         "is the slowdown multiplier (default 2.0). E.g. "
                         "--chaos 3:crash:2 kills device 2 before round 3; "
                         "crashes need --elastic to survive")
    ap.add_argument("--elastic", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="ring mode: absorb churn live — crashes shrink the "
                         "ring to the survivors (checkpoint-free recovery), "
                         "stragglers are EWMA-detected from stage timings "
                         "and repartitioned away (hysteresis-gated)")
    ap.add_argument("--no-packed", action="store_true",
                    help="ring mode: revert Phase A to the per-owner scan "
                         "(S separate M+F-1-tick pipelines per round) "
                         "instead of the default packed conveyor (one "
                         "S*M+F-1-tick stream, saving (S-1)(F-1) ticks)")
    ap.add_argument("--save", default=None,
                    help="checkpoint path (both modes): params + Adam "
                         "moments + policy + data cursor")
    ap.add_argument("--resume", default=None,
                    help="resume bit-reproducibly from a --save checkpoint "
                         "(ring mode restores the SAVED backend/stages/"
                         "slots/cache configuration; the corresponding "
                         "flags are ignored)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        import dataclasses
        per = cfg.layers_per_repeat
        if args.layers % per:
            raise SystemExit(f"--layers {args.layers} must be a multiple of "
                             f"{cfg.name}'s layers-per-repeat ({per})")
        cfg = dataclasses.replace(cfg, n_layers=args.layers,
                                  repeats=args.layers // per)
    tc = TrainConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                     learning_rate=args.lr, steps=args.steps,
                     unfreeze_interval=args.unfreeze_interval,
                     n_microbatches=args.microbatches)
    if args.mode == "pjit":
        if args.chaos or args.elastic:
            raise SystemExit("--chaos/--elastic are ring-mode features "
                             "(--mode ring)")
        out = train_pjit(cfg, tc, steps=args.steps, scheme=args.scheme,
                         policy=args.policy, save_path=args.save,
                         resume=args.resume)
    else:
        speeds = ([float(s) for s in args.device_speeds.split(",")]
                  if args.device_speeds else None)
        out = train_ring(cfg, tc, rounds=args.rounds, n_stages=args.stages,
                         trainer=args.trainer, policy=args.policy,
                         slots_per_epoch=args.slots_per_epoch or None,
                         cache_capacity=0 if args.no_cache
                         else args.cache_capacity,
                         packed=not args.no_packed,
                         cache_dtype=args.cache_dtype,
                         device_speeds=speeds,
                         tenants=args.tenants,
                         adapter_store=args.adapter_store,
                         chaos=args.chaos, elastic=args.elastic,
                         save_path=args.save, resume=args.resume)
    print(json.dumps(out["history"][-1], default=float))


if __name__ == "__main__":
    main()
