"""Training driver: RingAda fine-tuning with scheduled layer unfreezing.

Two execution modes:
  * ``--mode pjit`` (default): single- or multi-device data/tensor-parallel
    training with the static unfreeze boundary (staged re-jit per depth change).
  * ``--mode ring``: shard_map ring pipeline across ``--stages`` devices with
    rotating initiators (needs >= stages local devices, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).  The default ring
    driver is the fused ``RingExecutor`` (one donated executable per boundary,
    no per-iteration host sync); ``--trainer reference`` selects the unfused
    ``RingTrainer`` oracle.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch mbert-squad --steps 120 \
        --reduced --mode pjit
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.core import training
from repro.core.unfreeze import UnfreezeSchedule, boundary_schedule
from repro.data.pipeline import Batcher, RingBatcher, make_client_datasets, merged
from repro.models import params as prm
from repro.optim import adamw
from repro.checkpoint import checkpoint as ckpt


def train_pjit(cfg, tc: TrainConfig, *, steps: int, log_every: int = 10,
               scheme: str = "ringada", impl: str = "jnp",
               save_path: Optional[str] = None, log=print) -> Dict[str, Any]:
    """Single-process training loop with the paper's unfreeze schedule.

    scheme: 'ringada' (scheduled unfreezing) | 'all_hot' (PipeAdapter/Single-style
    baseline: every adapter trainable from step 0).
    """
    key = jax.random.key(tc.seed)
    params = prm.materialize(prm.param_defs(cfg), key, cfg.dtype)
    opt_state = adamw.init(training.full_trainable(params))
    qa = cfg.head_out == 2
    ds = merged(make_client_datasets(4, vocab=cfg.vocab_size,
                                     n_per_client=256, seq=tc.seq_len,
                                     seed=tc.seed, kind="qa" if qa else "lm"))
    batcher = Batcher(ds, tc.batch_size, seed=tc.seed)

    sched = UnfreezeSchedule.from_train_config(tc)
    if scheme == "all_hot":
        segs = [(0, steps, 0)]
    else:
        segs = boundary_schedule(cfg, sched, steps)

    history = []
    t0 = time.time()
    step_fns: Dict[int, Any] = {}
    for (s0, s1, boundary) in segs:
        if boundary not in step_fns:
            mk = (training.make_qa_train_step if qa
                  else training.make_train_step)
            step_fns[boundary] = jax.jit(mk(cfg, tc, boundary, impl=impl),
                                         donate_argnums=(0, 1))
        fn = step_fns[boundary]
        for step in range(s0, s1):
            batch = batcher.next()
            params, opt_state, metrics = fn(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, boundary=boundary,
                         depth=cfg.repeats - boundary,
                         wall_s=round(time.time() - t0, 2))
                history.append(m)
                acc = m.get("accuracy", m.get("f1", 0.0))
                log(f"step {step:5d} b={boundary:2d} "
                    f"loss={m['loss']:.4f} acc/f1={acc:.3f} "
                    f"({m['wall_s']}s)")
    if save_path:
        ckpt.save(save_path, params, step=steps, adapters_only=True)
    return {"history": history, "params": params, "opt_state": opt_state,
            "wall_s": time.time() - t0}


def train_ring(cfg, tc: TrainConfig, *, rounds: int, n_stages: int,
               log_every: int = 1, trainer: str = "fused",
               slots_per_epoch: Optional[int] = None,
               cache_capacity: Optional[int] = None,
               log=print) -> Dict[str, Any]:
    """Ring-pipeline training across ``n_stages`` devices.

    trainer='fused' (default): ``RingExecutor`` — the whole round (S
    owner-iterations + optimizer) is one donated executable and metrics stay on
    device between logging intervals (async dispatch: the host never blocks
    mid-interval).  trainer='reference': the unfused ``RingTrainer`` oracle.

    slots_per_epoch: epoch-stable batch slots (same slot => same examples every
    epoch).  With the fused trainer this enables the frozen-trunk activation
    cache: steady-state revisits of a (slot, boundary) key skip Phase A
    entirely; a boundary drop invalidates the cache (core/actcache.py).  The
    default ``None`` keeps the pre-cache behavior exactly: a fresh random draw
    every round, cache off (it would never hit) — epoch-style training over a
    fixed slot cycle is opt-in because it changes which data the model sees.
    cache_capacity defaults to slots_per_epoch; 0 disables the cache while
    keeping slotted batches.
    """
    from repro import compat
    from repro.core.executor import RingExecutor
    from repro.core.ring import RingTrainer
    from repro.launch.mesh import make_ring_mesh, require_devices

    if trainer not in ("fused", "reference"):
        raise ValueError(f"trainer must be 'fused' or 'reference', "
                         f"got {trainer!r}")
    require_devices(n_stages)
    if cfg.head_out is not None:
        raise ValueError(
            f"ring mode trains with the LM objective, but this config has a "
            f"task head (head_out={cfg.head_out}) — the loss would be "
            f"garbage/NaN. Use an LM config, or reduce with head_out=None "
            f"like examples/ring_finetune.py.")
    if cfg.repeats % n_stages != 0:
        raise ValueError(
            f"ring training needs repeats divisible by stages: "
            f"cfg.repeats={cfg.repeats}, --stages {n_stages}. Pick --stages "
            f"from the divisors of {cfg.repeats}, or a config/--reduced "
            f"variant with more repeats.")
    mesh = make_ring_mesh(n_stages)
    key = jax.random.key(tc.seed)
    params = prm.materialize(prm.param_defs(cfg), key, cfg.dtype)
    if trainer == "fused":
        cap = cache_capacity if cache_capacity is not None else (slots_per_epoch or 0)
        if not slots_per_epoch:
            cap = 0          # no stable slots => keys never repeat => no cache
        elif 0 < cap < slots_per_epoch:
            # round-robin slots + LRU: every slot is evicted before its
            # revisit, so every round pays capture overhead for 0% hits
            log(f"WARNING: cache_capacity {cap} < slots_per_epoch "
                f"{slots_per_epoch}: the cache will thrash (0% hits, "
                f"capture overhead every round) — raise the capacity or "
                f"disable the cache (cache_capacity=0)")
        drv = RingExecutor(cfg, tc, mesh, params, n_stages, tc.n_microbatches,
                           cache_capacity=cap)
    else:
        drv = RingTrainer(cfg, tc, mesh, params, n_stages, tc.n_microbatches)
    clients = make_client_datasets(n_stages, vocab=cfg.vocab_size,
                                   n_per_client=128, seq=tc.seq_len,
                                   seed=tc.seed)
    rb = RingBatcher(clients, tc.n_microbatches, tc.batch_size, seed=tc.seed,
                     slots_per_epoch=slots_per_epoch)

    history = []
    pending = []          # fused path: device-array metrics awaiting host sync
    t0 = time.time()

    def flush():
        for m in pending:
            m2 = RingExecutor.materialize_metrics(m)
            m2["wall_s"] = round(time.time() - t0, 2)
            history.append(m2)
        pending.clear()

    def cache_note(h):
        if "cache_hit_rate" not in h:
            return ""
        return (f" cache[hit={h['cache_hit_rate']:.0%} "
                f"inval={h['cache_invalidations']}]")

    with compat.set_mesh(mesh):
        for r in range(rounds):
            if slots_per_epoch:
                slot, tokens, labels = rb.next_slot()
            else:
                slot, (tokens, labels) = None, rb.next()
            if trainer == "fused":
                m = drv.round(tokens, labels, slot=slot)
                pending.append(m)
                if r % log_every == 0 or r == rounds - 1:
                    flush()                  # one host sync per interval
                    h = history[-1]
                    log(f"round {r:4d} loss={h['loss']:.4f} "
                        f"boundary={h['boundary']}{cache_note(h)} "
                        f"({h['wall_s']}s)")
            else:
                m = drv.round(tokens, labels)
                m["wall_s"] = round(time.time() - t0, 2)
                history.append(m)
                if r % log_every == 0:
                    log(f"round {r:4d} loss={m['loss']:.4f} "
                        f"boundary={m['boundary']} ({m['wall_s']}s)")
        flush()
    return {"history": history, "trainer": drv,
            "wall_s": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mbert-squad")
    ap.add_argument("--mode", choices=["pjit", "ring"], default="pjit")
    ap.add_argument("--scheme", choices=["ringada", "all_hot"],
                    default="ringada")
    ap.add_argument("--trainer", choices=["fused", "reference"],
                    default="fused",
                    help="ring driver: fused RingExecutor or the unfused "
                         "RingTrainer oracle")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--unfreeze-interval", type=int, default=40)
    ap.add_argument("--slots-per-epoch", type=int, default=0,
                    help="ring mode: epoch-stable batch slots (the activation "
                         "cache's key space; e.g. 8 enables the Phase-A-skip "
                         "cache); 0 (default) = streaming random batches, "
                         "cache off — the pre-cache behavior")
    ap.add_argument("--cache-capacity", type=int, default=None,
                    help="ring mode: boundary-activation cache entries "
                         "(default: slots-per-epoch; 0 disables the cache)")
    ap.add_argument("--no-cache", action="store_true",
                    help="ring mode: disable the frozen-trunk activation "
                         "cache (use for streaming/non-repeating data)")
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                     learning_rate=args.lr, steps=args.steps,
                     unfreeze_interval=args.unfreeze_interval)
    if args.mode == "pjit":
        out = train_pjit(cfg, tc, steps=args.steps, scheme=args.scheme,
                         save_path=args.save)
    else:
        out = train_ring(cfg, tc, rounds=args.rounds, n_stages=args.stages,
                         trainer=args.trainer,
                         slots_per_epoch=args.slots_per_epoch or None,
                         cache_capacity=0 if args.no_cache
                         else args.cache_capacity)
    print(json.dumps(out["history"][-1], default=float))


if __name__ == "__main__":
    main()
