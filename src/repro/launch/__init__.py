"""repro.launch"""
