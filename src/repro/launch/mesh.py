"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first jax init.
All mesh construction goes through ``repro.compat`` so the same code runs on
jax lines with and without ``AxisType`` / ``jax.set_mesh``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips; multi-pod adds pod=2 => 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_ring_mesh(n_stages: int) -> Mesh:
    """Ring-pipeline mesh over the 'stage' axis (CPU demos / tests)."""
    return compat.make_mesh((n_stages,), ("stage",))


def require_devices(n: int) -> None:
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"need {n} devices, have {have}. Set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} BEFORE "
            f"importing jax (dryrun.py does this automatically).")
