"""Abstract input specs for every (architecture x input-shape) combination.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation) plus the matching
PartitionSpecs for the production mesh — consumed by the dry-run and roofline.

Stubbed frontends (the one allowed carve-out): for ``vlm`` / ``audio`` archs the
``memory`` input carries pre-computed patch / frame embeddings ``[B, T_f, d_model]``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as sh
from repro.configs.base import InputShape, ModelConfig
from repro.models import kvcache
from repro.models import params as prm


def batch_rules(mesh: Mesh, global_batch: int) -> Dict[str, Any]:
    """Mesh rules with the batch axis disabled when it cannot shard evenly —
    B=1 long-context decode replicates batch and gives 'data' to the KV window."""
    rules = sh.default_rules(mesh)
    n_data = sh.data_axis_size(mesh)
    if global_batch % n_data != 0:
        rules = {**rules, "batch": None}
    return rules


def train_inputs(cfg: ModelConfig, shape: InputShape, mesh: Mesh
                 ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(abstract batch, batch partition specs) for a train step."""
    rules = batch_rules(mesh, shape.global_batch)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }
    specs = {
        "tokens": sh.spec_for(("batch", None), rules, (B, S)),
        "labels": sh.spec_for(("batch", None), rules, (B, S)),
    }
    if cfg.frontend is not None or cfg.enc_dec:
        Tf = cfg.n_frontend_tokens
        batch["memory"] = jax.ShapeDtypeStruct((B, Tf, cfg.d_model), jnp.bfloat16)
        specs["memory"] = sh.spec_for(("batch", None, None), rules,
                                      (B, Tf, cfg.d_model))
    return batch, specs


def prefill_inputs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    rules = batch_rules(mesh, shape.global_batch)
    B, S = shape.global_batch, shape.seq_len
    inputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    specs = {"tokens": sh.spec_for(("batch", None), rules, (B, S))}
    if cfg.frontend is not None or cfg.enc_dec:
        Tf = cfg.n_frontend_tokens
        inputs["memory"] = jax.ShapeDtypeStruct((B, Tf, cfg.d_model), jnp.bfloat16)
        specs["memory"] = sh.spec_for(("batch", None, None), rules,
                                      (B, Tf, cfg.d_model))
    return inputs, specs


def prefill_out_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """(logits, cache) output shardings — without these the freshly-built KV
    cache replicates per chip (64 GiB/chip at vision-11B prefill_32k scale)."""
    rules = batch_rules(mesh, shape.global_batch)
    B, S = shape.global_batch, shape.seq_len
    mem_len = cfg.n_frontend_tokens if (cfg.frontend or cfg.enc_dec) else 0
    cspecs = kvcache.cache_specs(cfg, B, S, rules, mem_len=mem_len)
    lspec = sh.spec_for(("batch", "vocab"), rules, (B, cfg.out_dim))
    return lspec, cspecs


def decode_inputs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """token + KV/state cache sized for a ``seq_len`` decode horizon."""
    rules = batch_rules(mesh, shape.global_batch)
    B, S = shape.global_batch, shape.seq_len
    mem_len = cfg.n_frontend_tokens if (cfg.frontend or cfg.enc_dec) else 0
    cache = kvcache.abstract_cache(cfg, B, S, mem_len=mem_len)
    cache_specs = kvcache.cache_specs(cfg, B, S, rules, mem_len=mem_len)
    inputs = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32), "cache": cache}
    specs = {"token": sh.spec_for(("batch", None), rules, (B, 1)),
             "cache": cache_specs}
    return inputs, specs


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    return prm.specs(prm.param_defs(cfg), sh.default_rules(mesh))


def abstract_params(cfg: ModelConfig) -> Any:
    return prm.abstract(prm.param_defs(cfg), cfg.dtype)


def trainable_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    """Specs for the trainable tree {adapters: tuple, head: ...} (full, b=0)."""
    ps = param_specs(cfg, mesh)
    return {"adapters": tuple(e["adapter"] for e in ps["blocks"]),
            "head": ps["head"]}


def abstract_opt_state(cfg: ModelConfig) -> Any:
    ap = abstract_params(cfg)
    tr = {"adapters": tuple(e["adapter"] for e in ap["blocks"]),
          "head": ap["head"]}
    f32 = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return {"m": f32(tr), "v": f32(tr),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    tr = trainable_specs(cfg, mesh)
    return {"m": tr, "v": tr, "count": P()}


def act_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> P:
    """Residual-stream constraint: [batch, seq, d_model-> model axis]."""
    rules = batch_rules(mesh, shape.global_batch)
    return sh.spec_for(("batch", None, "act_embed"), rules,
                       (shape.global_batch, shape.seq_len, cfg.d_model))
