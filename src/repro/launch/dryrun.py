import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) combo.

Proves the distribution config is coherent without hardware: ShapeDtypeStruct
inputs, pjit with explicit in/out shardings, ``.lower().compile()`` must succeed;
``memory_analysis()`` proves per-chip fit, ``cost_analysis()`` + the optimized HLO
feed the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay the first statements in this file: jax locks the
device count at first init, and the production meshes need 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b \
        --shape train_4k [--multi-pod] [--boundary N] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out DIR]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from repro import compat
from repro import roofline as rl
from repro.configs import INPUT_SHAPES, ASSIGNED, TrainConfig, get_config, shape_runnable
from repro.core import training
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                boundary: int = 0, remat: bool = True,
                keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                           "boundary": boundary, "status": "ok"}

    ok, reason = shape_runnable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    moe_groups = 32 if multi_pod else 16          # = data-parallel shards
    pspecs = inp.param_specs(cfg, mesh)
    aparams = inp.abstract_params(cfg)
    aspec = inp.act_spec(cfg, shape, mesh)
    tc = TrainConfig()

    t0 = time.time()
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            batch, bspecs = inp.train_inputs(cfg, shape, mesh)
            ospecs = inp.opt_state_specs(cfg, mesh)
            ostate = inp.abstract_opt_state(cfg)
            step = training.make_train_step(cfg, tc, boundary, remat=remat,
                                            act_spec=aspec,
                                            moe_groups=moe_groups)
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, ostate, batch)
        elif shape.kind == "prefill":
            inputs, ispecs = inp.prefill_inputs(cfg, shape, mesh)
            step = training.make_prefill_step(cfg, shape.seq_len, act_spec=aspec,
                                              moe_groups=moe_groups)
            args = [aparams, inputs["tokens"]]
            shards = [pspecs, ispecs["tokens"]]
            if "memory" in inputs:
                args.append(inputs["memory"])
                shards.append(ispecs["memory"])
            jitted = jax.jit(step, in_shardings=tuple(shards),
                             out_shardings=inp.prefill_out_specs(
                                 cfg, shape, mesh))
            lowered = jitted.lower(*args)
        else:  # decode
            inputs, ispecs = inp.decode_inputs(cfg, shape, mesh)
            step = training.make_serve_step(cfg, act_spec=aspec)
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ispecs["token"],
                                           ispecs["cache"]),
                             out_shardings=(None, None, ispecs["cache"]),
                             donate_argnums=(2,))
            lowered = jitted.lower(aparams, inputs["token"], inputs["cache"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    mf = rl.model_flops(cfg, shape)
    analytic = rl.analytic_flops(cfg, shape)
    roof = rl.build(arch, shape, mesh_name, chips, cost, coll, mf, analytic)

    rec.update(
        chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            peak_bytes=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        ),
        cost={k: cost.get(k) for k in ("flops", "bytes accessed")},
        collectives=coll,
        roofline=roof.to_dict(),
        hlo_bytes=len(hlo),
    )
    if keep_hlo:
        rec["hlo"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--boundary", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else \
        [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for a, s, mp in combos:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"{a}__{s}__{mesh_name}" + (
            f"__b{args.boundary}" if args.boundary else "")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            rec = json.load(open(path))
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skip"
            n_fail += rec["status"] == "fail"
            continue
        print(f"[run]    {tag} ...", flush=True)
        try:
            rec = lower_combo(a, s, multi_pod=mp, boundary=args.boundary,
                              remat=not args.no_remat)
        except Exception as e:  # a failure here is a sharding bug — record it
            rec = {"arch": a, "shape": s, "mesh": mesh_name, "status": "fail",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            n_ok += 1
            r = rec["roofline"]
            print(f"         ok: compile={rec['compile_s']}s "
                  f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/chip "
                  f"dominant={r['dominant']} "
                  f"terms=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                  f"{r['collective_s']:.2e})s", flush=True)
        elif rec["status"] == "skip":
            n_skip += 1
            print(f"         skip: {rec['reason']}")
        else:
            n_fail += 1
            print(f"         FAIL: {rec['error']}")
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
