"""PEFT-masked AdamW.

Optimizer state exists only for the paper's trainable set (adapters + head) — this
is the memory advantage RingAda inherits from adapter fine-tuning: for a 7B backbone
the moments cover ~2% of parameters.

Moments for the adapter stacks are kept *full-size* ``[R, ...]`` so the optimizer
state pytree is stable while the unfreeze boundary moves; rows below the boundary are
frozen with a static row mask (their gradients are exactly zero anyway, but the mask
also stops weight decay and moment decay from touching them — the paper updates only
unfrozen adapters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Array = jax.Array


def lr_at(tc: TrainConfig, step: Array) -> Array:
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(1.0, (s + 1.0) / max(tc.warmup_steps, 1))
    return tc.learning_rate * warm


def init(trainable_full: Any) -> Dict[str, Any]:
    """trainable_full: the *full* (boundary=0) trainable tree."""
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(trainable_full), "v": zeros(trainable_full),
            "count": jnp.zeros((), jnp.int32)}


def _pad_adapters(grads_sliced: Any, boundary: int) -> Any:
    """Pad per-entry adapter grads [R-b, ...] back to [R, ...] with zero rows."""
    def pad(x):
        if boundary == 0:
            return x
        z = jnp.zeros((boundary,) + x.shape[1:], x.dtype)
        return jnp.concatenate([z, x], axis=0)

    return jax.tree.map(pad, grads_sliced)


def update(grads: Dict[str, Any], opt_state: Dict[str, Any],
           trainable_full: Dict[str, Any], tc: TrainConfig, boundary: int,
           ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """One AdamW step.

    grads: {"adapters": tuple of sliced [R-b,...] trees, "head": ...}
    trainable_full / opt_state moments: full-size trees.
    Returns (new_trainable_full, new_opt_state).
    """
    g_full = {"adapters": tuple(_pad_adapters(g, boundary)
                                for g in grads["adapters"]),
              "head": grads["head"]}

    count = opt_state["count"] + 1
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    lr = lr_at(tc, count)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def row_mask(x):
        if boundary == 0:
            return jnp.ones((1,) * x.ndim, jnp.float32)
        mask = (jnp.arange(x.shape[0]) >= boundary).astype(jnp.float32)
        return mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))

    def leaf(path_is_adapter):
        def f(g, m, v, p):
            gf = g.astype(jnp.float32)
            mask = row_mask(g) if path_is_adapter else jnp.float32(1.0)
            m2 = jnp.where(mask > 0, b1 * m + (1 - b1) * gf, m)
            v2 = jnp.where(mask > 0, b2 * v + (1 - b2) * gf * gf, v)
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            upd = upd + tc.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * upd * mask
            return m2, v2, new_p.astype(p.dtype)
        return f

    new_state: Dict[str, Any] = {"count": count}
    new_trainable: Dict[str, Any] = {}

    # adapters (per pattern entry)
    fa = leaf(True)
    m_out, v_out, p_out = [], [], []
    for gi, mi, vi, pi in zip(g_full["adapters"], opt_state["m"]["adapters"],
                              opt_state["v"]["adapters"],
                              trainable_full["adapters"]):
        trip = jax.tree.map(fa, gi, mi, vi, pi)
        m_out.append(jax.tree.map(lambda t: t[0], trip, is_leaf=lambda x: isinstance(x, tuple)))
        v_out.append(jax.tree.map(lambda t: t[1], trip, is_leaf=lambda x: isinstance(x, tuple)))
        p_out.append(jax.tree.map(lambda t: t[2], trip, is_leaf=lambda x: isinstance(x, tuple)))
    # head
    fh = leaf(False)
    trip_h = jax.tree.map(fh, g_full["head"], opt_state["m"]["head"],
                          opt_state["v"]["head"], trainable_full["head"])
    is_t = lambda x: isinstance(x, tuple)
    new_state["m"] = {"adapters": tuple(m_out),
                      "head": jax.tree.map(lambda t: t[0], trip_h, is_leaf=is_t)}
    new_state["v"] = {"adapters": tuple(v_out),
                      "head": jax.tree.map(lambda t: t[1], trip_h, is_leaf=is_t)}
    new_trainable = {"adapters": tuple(p_out),
                     "head": jax.tree.map(lambda t: t[2], trip_h, is_leaf=is_t)}
    return new_trainable, new_state


def opt_state_bytes(opt_state) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state))
