"""PEFT-masked AdamW — the one optimizer implementation every path shares.

Optimizer state exists only for the paper's trainable set (adapters + head) — this
is the memory advantage RingAda inherits from adapter fine-tuning: for a 7B backbone
the moments cover ~2% of parameters.

Three layers of API, all built on the same leaf math (``leaf_update``):

  * ``leaf_update`` / ``init_moments`` / ``tree_update`` — the shared masked-Adam
    primitive.  Used directly by the fused ring executor (``core/executor.py``),
    which runs the update *inside* its jitted, donated step with a stage mask,
    and by the reference ``RingTrainer`` (``core/ring.py``).
  * ``init`` / ``update`` — the pjit-path API over the full trainable tree
    (``core/training.py``, ``launch/train.py``): bias-corrected, warmup lr, row
    mask below the unfreeze boundary.
  * ``lr_at`` — the warmup schedule, shared by both.

Masking semantics (paper: only unfrozen adapters are updated): where the mask is
zero, the moments do not decay and the parameter does not move — a frozen row is
bit-identical before and after the step, not merely "gradient-zero".

Moments for the adapter stacks are kept *full-size* ``[R, ...]`` (pjit path) or
stage-stacked ``[S, lps, ...]`` (ring path) so the optimizer-state pytree is
stable while the unfreeze boundary moves.  Multi-tenant rings add one interior
tenant axis (``[S, T, lps, ...]`` adapters, ``[T, ...]`` head) via
``tenant_stack`` — the update math is unchanged because ``leaf_update`` is
elementwise and the stage mask broadcasts over the extra axis.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Array = jax.Array
MaskLike = Union[None, Array, float, Callable[[Array], Any]]


def lr_at(tc: TrainConfig, step: Array) -> Array:
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(1.0, (s + 1.0) / max(tc.warmup_steps, 1))
    return tc.learning_rate * warm


# ---------------------------------------------------------------------------
# Shared masked-Adam primitive
# ---------------------------------------------------------------------------


def init_moments(tree: Any) -> Tuple[Any, Any]:
    """(m, v) float32 zeros shaped like ``tree``."""
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return zeros(tree), zeros(tree)


def tenant_stack(tree: Any, n_tenants: int, *, axis: int = 0) -> Any:
    """Tile every leaf with a tenant axis of size ``n_tenants`` at ``axis``.

    The multi-tenant ring executor stacks adapters/moments per tenant and
    runs ``tree_update`` on the stacked trees unchanged: ``leaf_update`` is
    elementwise and the executor's scalar stage mask broadcasts over the
    extra axis, so per-tenant updates are bit-identical to T independent
    single-tenant updates.  All tenants start from the SAME initial values —
    that shared init is what keeps frozen adapter rows bit-identical across
    tenants (the frozen-region invariant the shared Phase-A trunk relies on).
    """
    return jax.tree.map(
        lambda x: jnp.stack([x] * n_tenants, axis=axis), tree)


def leaf_update(g: Array, m: Array, v: Array, p: Array, *, lr, tc: TrainConfig,
                mask: MaskLike = None,
                bias_correction: Optional[Tuple[Array, Array]] = None,
                ) -> Tuple[Array, Array, Array]:
    """One masked AdamW update on a single leaf -> (m2, v2, p2).

    ``mask`` broadcasts against the leaf; where it is zero neither the moments
    nor the parameter move.  ``bias_correction=(bc1, bc2)`` enables the
    bias-corrected form (pjit path); ``None`` is the raw form the ring paths
    use (constant lr, no correction — the paper's per-client update).
    """
    gf = g.astype(jnp.float32)
    mk = jnp.float32(1.0) if mask is None else mask
    m2 = jnp.where(mk > 0, tc.beta1 * m + (1 - tc.beta1) * gf, m)
    v2 = jnp.where(mk > 0, tc.beta2 * v + (1 - tc.beta2) * gf * gf, v)
    if bias_correction is None:
        mhat, vhat = m2, v2
    else:
        mhat, vhat = m2 / bias_correction[0], v2 / bias_correction[1]
    upd = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
    p2 = (p.astype(jnp.float32) - lr * upd * mk).astype(p.dtype)
    return m2, v2, p2


def _unzip3(trip: Any) -> Tuple[Any, Any, Any]:
    is_t = lambda x: isinstance(x, tuple)
    pick = lambda i: jax.tree.map(lambda t: t[i], trip, is_leaf=is_t)
    return pick(0), pick(1), pick(2)


def tree_update(grads: Any, m: Any, v: Any, params: Any, tc: TrainConfig, *,
                lr, mask: MaskLike = None,
                bias_correction: Optional[Tuple[Array, Array]] = None,
                ) -> Tuple[Any, Any, Any]:
    """Masked AdamW over a pytree -> (new_params, new_m, new_v).

    ``mask`` is either broadcastable against every leaf (e.g. the executor's
    scalar stage mask) or a callable ``leaf -> mask`` (e.g. the pjit path's
    per-leaf row mask).
    """
    mask_fn = mask if callable(mask) else (lambda _leaf: mask)
    trip = jax.tree.map(
        lambda g, mi, vi, pi: leaf_update(g, mi, vi, pi, lr=lr, tc=tc,
                                          mask=mask_fn(pi),
                                          bias_correction=bias_correction),
        grads, m, v, params)
    m2, v2, p2 = _unzip3(trip)
    return p2, m2, v2


# ---------------------------------------------------------------------------
# pjit-path API (full trainable tree, boundary row mask, warmup + bias corr.)
# ---------------------------------------------------------------------------


def init(trainable_full: Any) -> Dict[str, Any]:
    """trainable_full: the *full* (boundary=0) trainable tree."""
    m, v = init_moments(trainable_full)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def _pad_adapters(grads_sliced: Any, boundary: int) -> Any:
    """Pad per-entry adapter grads [R-b, ...] back to [R, ...] with zero rows."""
    def pad(x):
        if boundary == 0:
            return x
        z = jnp.zeros((boundary,) + x.shape[1:], x.dtype)
        return jnp.concatenate([z, x], axis=0)

    return jax.tree.map(pad, grads_sliced)


def update(grads: Dict[str, Any], opt_state: Dict[str, Any],
           trainable_full: Dict[str, Any], tc: TrainConfig, boundary: int,
           ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """One AdamW step (pjit path).

    grads: {"adapters": tuple of sliced [R-b,...] trees, "head": ...}
    trainable_full / opt_state moments: full-size trees.
    Returns (new_trainable_full, new_opt_state).
    """
    g_full = {"adapters": tuple(_pad_adapters(g, boundary)
                                for g in grads["adapters"]),
              "head": grads["head"]}

    count = opt_state["count"] + 1
    lr = lr_at(tc, count)
    c = count.astype(jnp.float32)
    bc = (1.0 - tc.beta1 ** c, 1.0 - tc.beta2 ** c)

    def row_mask(x):
        if boundary == 0:
            return jnp.ones((1,) * x.ndim, jnp.float32)
        mask = (jnp.arange(x.shape[0]) >= boundary).astype(jnp.float32)
        return mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))

    m_out, v_out, p_out = [], [], []
    for gi, mi, vi, pi in zip(g_full["adapters"], opt_state["m"]["adapters"],
                              opt_state["v"]["adapters"],
                              trainable_full["adapters"]):
        pe, me, ve = tree_update(gi, mi, vi, pi, tc, lr=lr, mask=row_mask,
                                 bias_correction=bc)
        m_out.append(me)
        v_out.append(ve)
        p_out.append(pe)
    ph, mh, vh = tree_update(g_full["head"], opt_state["m"]["head"],
                             opt_state["v"]["head"], trainable_full["head"],
                             tc, lr=lr, bias_correction=bc)
    new_state = {"count": count,
                 "m": {"adapters": tuple(m_out), "head": mh},
                 "v": {"adapters": tuple(v_out), "head": vh}}
    new_trainable = {"adapters": tuple(p_out), "head": ph}
    return new_trainable, new_state


def opt_state_bytes(opt_state) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state))
