"""repro.optim"""
