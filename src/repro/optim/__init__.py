"""repro.optim — shared optimizer interface.

``adamw`` exposes the one masked-AdamW implementation used by every training
path: the fused ring executor (in-jit, stage-masked), the reference ring
trainer, and the pjit trainer (boundary row mask + warmup + bias correction).
"""
from repro.optim import adamw
from repro.optim.adamw import (init, init_moments, leaf_update, lr_at,
                               opt_state_bytes, tree_update, update)

__all__ = ["adamw", "init", "init_moments", "leaf_update", "lr_at",
           "opt_state_bytes", "tree_update", "update"]
