"""KV / recurrent-state caches for serving.

Cache layout (one entry per layer-pattern position, stacked ``[repeats, count, ...]``):

  dense / moe : {"k": [R,C,B,Ck,K,hd], "v": ...}
  cross       : dense + {"xk": [R,C,B,Tm,K,hd], "xv": ...}
  rwkv        : {"state": [R,C,B,H,hd,hd] f32, "px_tm": [R,C,B,D], "px_cm": [R,C,B,D]}
  hymba       : dense + {"ssm": [R,C,B,di,N] f32, "conv": [R,C,B,W-1,di]}

plus top-level bookkeeping shared by all layers:

  {"pos": [B, Ck] int32   (absolute position held in each slot, -1 = empty),
   "next": [B] int32      (number of tokens generated so far)}

Sliding-window archs keep a ring buffer of ``n_sink + window`` slots (sink slots are
never evicted — Hymba meta tokens act as attention sinks); full-attention archs keep
``seq_len`` slots. RWKV caches O(1) state only.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro import sharding as sh

Array = jax.Array


def n_sink(cfg: ModelConfig) -> int:
    return 128 if any(k == "hymba" for k, _ in cfg.pattern) else 0


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Number of KV slots required to decode at position ``seq_len``."""
    if cfg.sliding_window is not None:
        return min(seq_len, n_sink(cfg) + cfg.sliding_window)
    return seq_len


def write_slot(cfg: ModelConfig, pos: Array, seq_len: int) -> Array:
    """Ring-buffer slot for absolute position ``pos`` (any int array)."""
    ck = cache_len(cfg, seq_len)
    ns = n_sink(cfg)
    if cfg.sliding_window is None or ck == seq_len:
        return pos
    w = ck - ns
    return jnp.where(pos < ns, pos, ns + (pos - ns) % w)


# ---------------------------------------------------------------------------
# Structure builders
# ---------------------------------------------------------------------------


def _entry_struct(cfg: ModelConfig, kind: str, batch: int, ck: int,
                  mem_len: int, dtype) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """(shape, dtype, logical axes) per leaf for one layer (unstacked)."""
    K, hd, D = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    kv_dt = jnp.int8 if cfg.kv_quant else dtype
    kv = lambda: (((batch, ck, K, hd), kv_dt, ("batch", "kv_seq", "kv_heads", None)))
    scale = lambda: (((batch, ck, K, 1), dtype, ("batch", "kv_seq", "kv_heads", None)))
    out: Dict[str, Any] = {}
    if kind in ("dense", "moe", "cross", "hymba"):
        out["k"] = kv()
        out["v"] = kv()
        if cfg.kv_quant:
            out["k_s"] = scale()
            out["v_s"] = scale()
    if kind == "cross":
        out["xk"] = ((batch, mem_len, K, hd), dtype,
                     ("batch", "frontend_seq", "kv_heads", None))
        out["xv"] = ((batch, mem_len, K, hd), dtype,
                     ("batch", "frontend_seq", "kv_heads", None))
    if kind == "rwkv":
        H = D // cfg.ssm.head_dim
        rhd = cfg.ssm.head_dim
        out["state"] = ((batch, H, rhd, rhd), jnp.float32,
                        ("batch", "heads", None, None))
        out["px_tm"] = ((batch, D), dtype, ("batch", "act_embed"))
        out["px_cm"] = ((batch, D), dtype, ("batch", "act_embed"))
    if kind == "hymba":
        di = cfg.n_heads * cfg.head_dim
        N = cfg.ssm.state_size
        W = cfg.ssm.conv_width
        out["ssm"] = ((batch, di, N), jnp.float32, ("batch", "heads", None))
        out["conv"] = ((batch, W - 1, di), dtype, ("batch", None, "heads"))
    return out


def _build(cfg: ModelConfig, batch: int, seq_len: int, mem_len: int,
           dtype, make_leaf) -> Dict[str, Any]:
    ck = cache_len(cfg, seq_len)
    layers = []
    for kind, count in cfg.pattern:
        entry = {}
        for name, (shape, dt, logical) in _entry_struct(
                cfg, kind, batch, ck, mem_len, dtype).items():
            entry[name] = make_leaf((cfg.repeats, count) + shape, dt,
                                    ("layers", "layers") + logical)
        layers.append(entry)
    cache = {
        "layers": tuple(layers),
        "pos": make_leaf((batch, ck), jnp.int32, ("batch", "kv_seq")),
        "next": make_leaf((batch,), jnp.int32, ("batch",)),
    }
    return cache


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               mem_len: int = 0, dtype=jnp.bfloat16) -> Dict[str, Any]:
    def leaf(shape, dt, logical):
        if dt == jnp.int32:
            return -jnp.ones(shape, dt) if len(shape) == 2 else jnp.zeros(shape, dt)
        return jnp.zeros(shape, dt)

    c = _build(cfg, batch, seq_len, mem_len, dtype, leaf)
    c["next"] = jnp.zeros((batch,), jnp.int32)
    return c


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
                   mem_len: int = 0, dtype=jnp.bfloat16) -> Dict[str, Any]:
    return _build(cfg, batch, seq_len, mem_len, dtype,
                  lambda shape, dt, logical: jax.ShapeDtypeStruct(shape, dt))


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, rules,
                *, mem_len: int = 0, dtype=jnp.bfloat16) -> Dict[str, Any]:
    return _build(cfg, batch, seq_len, mem_len, dtype,
                  lambda shape, dt, logical: sh.spec_for(logical, rules, shape))


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
