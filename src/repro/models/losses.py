"""Losses and metrics."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _ce_terms(logits: Array, labels: Array, mask: Array):
    """(sum nll, sum correct, sum mask) over all positions — fp32 internals."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    correct = ((jnp.argmax(lf, -1) == labels) * mask)
    return nll.sum(), correct.sum(), mask.sum()


def cross_entropy(logits: Array, labels: Array,
                  mask: Optional[Array] = None,
                  chunk: Optional[int] = None,
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Token-level CE. logits [B,S,V] (any float dtype), labels [B,S] int32.

    Stable fp32 logsumexp; works with vocab-sharded logits under pjit. With
    ``chunk`` set and S divisible, the sequence is processed in checkpointed
    chunks so the fp32 logit copies (8.4 GiB/chip at llama4's 202k vocab,
    train_4k) never materialize whole — recomputed per chunk in the backward.
    """
    B, S = labels.shape
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    mask = mask.astype(jnp.float32)

    if chunk and S > chunk and S % chunk == 0:
        nc = S // chunk
        lg = logits.reshape(B, nc, chunk, -1).swapaxes(0, 1)
        lb = labels.reshape(B, nc, chunk).swapaxes(0, 1)
        mk = mask.reshape(B, nc, chunk).swapaxes(0, 1)
        terms = jax.checkpoint(_ce_terms)

        def body(carry, xs):
            n, c, m = terms(*xs)
            return (carry[0] + n, carry[1] + c, carry[2] + m), None

        (nll_sum, corr, msum), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)),
            (lg, lb, mk))
    else:
        nll_sum, corr, msum = _ce_terms(logits, labels, mask)

    denom = jnp.maximum(msum, 1.0)
    loss = nll_sum / denom
    acc = corr / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def qa_span_loss(logits: Array, starts: Array, ends: Array
                 ) -> Tuple[Array, Dict[str, Array]]:
    """SQuAD-style span prediction: logits [B,S,2] -> start/end distributions.

    Used by the mBERT+SQuAD paper configuration; EM / F1 computed on argmax spans
    (token-level F1, the standard SQuAD metric applied to synthetic spans).
    """
    lf = logits.astype(jnp.float32)
    sl, el = lf[..., 0], lf[..., 1]

    def ce1(l, y):
        return jax.nn.logsumexp(l, -1) - jnp.take_along_axis(l, y[:, None], 1)[:, 0]

    loss = jnp.mean(ce1(sl, starts) + ce1(el, ends)) / 2.0
    ps, pe = jnp.argmax(sl, -1), jnp.argmax(el, -1)
    em = jnp.mean(((ps == starts) & (pe == ends)).astype(jnp.float32))
    # token-level F1 between predicted and gold spans
    lo = jnp.maximum(ps, starts)
    hi = jnp.minimum(pe, ends)
    inter = jnp.maximum(hi - lo + 1, 0).astype(jnp.float32)
    len_p = jnp.maximum(pe - ps + 1, 1).astype(jnp.float32)
    len_g = jnp.maximum(ends - starts + 1, 1).astype(jnp.float32)
    prec, rec = inter / len_p, inter / len_g
    f1 = jnp.mean(jnp.where(inter > 0, 2 * prec * rec / (prec + rec + 1e-9), 0.0))
    return loss, {"loss": loss, "em": em, "f1": f1}
