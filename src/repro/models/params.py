"""Parameter definitions for every block kind.

A model's parameters are described *declaratively* as a pytree of :class:`PD`
(param-def) leaves. One definition tree serves three purposes:

  * ``materialize(defs, key)``   -> real initialized arrays (smoke tests / training)
  * ``abstract(defs)``           -> ShapeDtypeStruct stand-ins (multi-pod dry-run)
  * ``specs(defs, rules)``       -> PartitionSpec tree (pjit in_shardings)

which guarantees init / sharding / dry-run can never drift apart.

Layer stacking: for each layer-pattern entry ``(kind, count)`` the block's leaves are
stacked with leading dims ``[repeats, count, ...]`` (logical axes ``layers, layers``),
so the transformer scans over repeats (outer) and count (inner) with compact HLO.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro import sharding as sh

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PD:
    """Declarative parameter definition."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | rwkv_decay | arange_log
    scale: Optional[float] = None  # stddev for normal; default fan-in
    dtype: Optional[str] = None    # override model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _stack(defs: Any, repeats: int, count: int) -> Any:
    """Prepend [repeats, count] stacking dims to every PD leaf."""

    def f(pd: PD) -> PD:
        return PD((repeats, count) + pd.shape, ("layers", "layers") + pd.logical,
                  pd.init, pd.scale, pd.dtype)

    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, PD))


# ---------------------------------------------------------------------------
# Shared sub-modules
# ---------------------------------------------------------------------------


def norm_defs(cfg: ModelConfig) -> Dict[str, PD]:
    d = {"scale": PD((cfg.d_model,), ("norm",), "ones", dtype="float32")}
    if cfg.norm == "layernorm":
        d["bias"] = PD((cfg.d_model,), ("norm",), "zeros", dtype="float32")
    return d


def adapter_defs(cfg: ModelConfig) -> Dict[str, PD]:
    """The paper's serial adapter: h <- h + sigma(h Wd) Wu  (eq. 1)."""
    m = cfg.adapter.bottleneck
    return {
        "w_down": PD((cfg.d_model, m), ("embed", "bottleneck")),
        "w_up": PD((m, cfg.d_model), ("bottleneck", "embed"),
                   "zeros" if cfg.adapter.zero_init_up else "normal"),
    }


def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, PD]:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = {
        "wq": PD((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": PD((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PD((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PD((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        d["bq"] = PD((H, hd), ("heads", "head_dim"), "zeros")
        d["bk"] = PD((K, hd), ("kv_heads", "head_dim"), "zeros")
        d["bv"] = PD((K, hd), ("kv_heads", "head_dim"), "zeros")
    return d


def ffn_defs(cfg: ModelConfig) -> Dict[str, PD]:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.glu:
        return {
            "w_gate": PD((D, F), ("embed", "ffn")),
            "w_up": PD((D, F), ("embed", "ffn")),
            "w_down": PD((F, D), ("ffn", "embed")),
        }
    d = {
        "w_in": PD((D, F), ("embed", "ffn")),
        "w_out": PD((F, D), ("ffn", "embed")),
    }
    if cfg.norm == "layernorm":  # BERT-era archs carry FFN biases
        d["b_in"] = PD((F,), ("ffn",), "zeros")
        d["b_out"] = PD((D,), ("embed",), "zeros")
    return d


def moe_defs(cfg: ModelConfig) -> Dict[str, PD]:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    # expert weights: FSDP the d_model dim (400B scale) or keep expert-sharded
    # only (small experts; avoids the per-layer FSDP all-gather)
    ed = "embed" if m.fsdp_experts else "expert_embed"
    d = {
        "router": PD((D, E), ("embed", "experts"), scale=0.02),
        "we_gate": PD((E, D, F), ("experts", ed, "expert_ffn")),
        "we_up": PD((E, D, F), ("experts", ed, "expert_ffn")),
        "we_down": PD((E, F, D), ("experts", "expert_ffn", ed)),
    }
    if getattr(m, "n_shared", 0):
        pass  # shared experts folded into w_shared below when configured
    # one shared expert (DeepSeek/Llama-4 style) — always present for moe blocks
    d["ws_gate"] = PD((D, F), ("embed", "ffn"))
    d["ws_up"] = PD((D, F), ("embed", "ffn"))
    d["ws_down"] = PD((F, D), ("ffn", "embed"))
    return d


def rwkv_defs(cfg: ModelConfig) -> Dict[str, PD]:
    """RWKV-6 (Finch): data-dependent token-shift + decay via LoRA."""
    D = cfg.d_model
    hd = cfg.ssm.head_dim
    H = D // hd
    lora = cfg.ssm.decay_lora
    F = cfg.d_ff
    return {
        # --- time mix ---
        "mu": PD((5, D), (None, "embed"), "normal", scale=0.02),     # r,k,v,w,g base mix
        "tm_w1": PD((D, 5 * 32), ("embed", None), scale=0.02),       # ddlerp lora A
        "tm_w2": PD((5, 32, D), (None, "lora", "embed"), scale=0.02),
        "dd_w1": PD((D, lora), ("embed", "lora"), scale=0.02),       # decay lora A
        "dd_w2": PD((lora, D), ("lora", "embed"), scale=0.02),
        "decay_base": PD((H, hd), ("heads", "head_dim"), "rwkv_decay"),
        "bonus_u": PD((H, hd), ("heads", "head_dim"), "normal", scale=0.5),
        "wr": PD((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": PD((D, H, hd), ("embed", "heads", "head_dim")),
        "wv": PD((D, H, hd), ("embed", "heads", "head_dim")),
        "wg": PD((D, H, hd), ("embed", "heads", "head_dim")),
        "wo": PD((H, hd, D), ("heads", "head_dim", "embed")),
        "ln_x": PD((D,), ("norm",), "ones", dtype="float32"),        # group-norm scale
        # --- channel mix ---
        "mu_ck": PD((D,), ("embed",), "normal", scale=0.02),
        "mu_cr": PD((D,), ("embed",), "normal", scale=0.02),
        "wk_c": PD((D, F), ("embed", "ffn")),
        "wv_c": PD((F, D), ("ffn", "embed")),
        "wr_c": PD((D, D), ("embed", "act_embed")),
    }


def mamba_defs(cfg: ModelConfig) -> Dict[str, PD]:
    """Mamba-style selective SSM head bank (the SSM half of a Hymba block)."""
    D = cfg.d_model
    di = cfg.n_heads * cfg.head_dim          # d_inner matches attention width
    N = cfg.ssm.state_size
    R = cfg.ssm.dt_rank
    W = cfg.ssm.conv_width
    return {
        "in_proj": PD((D, di), ("embed", "heads")),
        "conv_w": PD((W, di), ("conv", "heads"), "normal", scale=0.2),
        "x_proj": PD((di, R + 2 * N), ("heads", None)),
        "dt_proj": PD((R, di), ("lora", "heads"), scale=0.1),
        "dt_bias": PD((di,), ("heads",), "zeros"),
        "a_log": PD((di, N), ("heads", "state"), "arange_log"),
        "d_skip": PD((di,), ("heads",), "ones"),
    }


# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, kind: str, causal: bool = True) -> Dict[str, Any]:
    if kind == "dense":
        return {
            "ln1": norm_defs(cfg), "attn": attn_defs(cfg),
            "ln2": norm_defs(cfg), "ffn": ffn_defs(cfg),
            "adapter": adapter_defs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": norm_defs(cfg), "attn": attn_defs(cfg),
            "ln2": norm_defs(cfg), "moe": moe_defs(cfg),
            "adapter": adapter_defs(cfg),
        }
    if kind == "cross":
        return {
            "ln1": norm_defs(cfg), "attn": attn_defs(cfg),
            "lnx": norm_defs(cfg), "xattn": attn_defs(cfg, cross=True),
            "xgate": PD((1,), (None,), "zeros"),   # tanh-gated cross-attn (llama-3.2V)
            "ln2": norm_defs(cfg), "ffn": ffn_defs(cfg),
            "adapter": adapter_defs(cfg),
        }
    if kind == "rwkv":
        return {
            "ln1": norm_defs(cfg), "ln2": norm_defs(cfg),
            "rwkv": rwkv_defs(cfg),
            "adapter": adapter_defs(cfg),
        }
    if kind == "hymba":
        di = cfg.n_heads * cfg.head_dim
        return {
            "ln1": norm_defs(cfg),
            "attn": attn_defs(cfg),
            "ssm": mamba_defs(cfg),
            "norm_attn": PD((di,), ("heads",), "ones", dtype="float32"),
            "norm_ssm": PD((di,), ("heads",), "ones", dtype="float32"),
            "ln2": norm_defs(cfg), "ffn": ffn_defs(cfg),
            "adapter": adapter_defs(cfg),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": {"tok": PD((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                            scale=0.02)},
        "final_norm": norm_defs(cfg),
        "head": {"w": PD((cfg.d_model, cfg.out_dim),
                         ("embed", "vocab" if cfg.head_out is None else None))},
    }
    if not cfg.rope:
        defs["embed"]["pos"] = PD((min(cfg.max_seq_len, 8192), cfg.d_model),
                                  ("pos", "embed"), scale=0.02)
    if any(k == "hymba" for k, _ in cfg.pattern):
        defs["meta"] = PD((128, cfg.d_model), ("pos", "embed"), scale=0.02)
    # decoder (or the only) stack: tuple aligned with cfg.pattern
    defs["blocks"] = tuple(
        _stack(block_defs(cfg, kind), cfg.repeats, count)
        for kind, count in cfg.pattern
    )
    if cfg.enc_dec:
        enc_cfg = dataclasses.replace(cfg, qkv_bias=False)
        defs["encoder"] = {
            "blocks": (_stack(block_defs(enc_cfg, "dense"), cfg.n_enc_layers, 1),),
            "final_norm": norm_defs(cfg),
        }
    return defs


# ---------------------------------------------------------------------------
# Materialization / abstraction
# ---------------------------------------------------------------------------

_IS_PD = lambda x: isinstance(x, PD)


def _init_leaf(pd: PD, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    dt = jnp.dtype(pd.dtype) if pd.dtype else dtype
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dt)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dt)
    if pd.init == "rwkv_decay":
        # per-channel decay prior in (-6, -0.5): w = exp(-exp(x))
        n = int(np.prod(pd.shape))
        v = jnp.linspace(-6.0, -0.5, n, dtype=jnp.float32).reshape(pd.shape)
        return v.astype(dt)
    if pd.init == "arange_log":
        # mamba A init: -[1..N] broadcast over channels, stored as log
        N = pd.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), pd.shape)
        return jnp.log(a).astype(dt)
    # normal with fan-in default
    fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
    # stacked leaves: ignore the two leading layer dims when inferring fan-in
    if pd.logical[:2] == ("layers", "layers") and len(pd.shape) >= 4:
        fan_in = pd.shape[-2]
    scale = pd.scale if pd.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, pd.shape, jnp.float32) * scale).astype(dt)


def materialize(defs: Any, key: jax.Array, dtype: str = "bfloat16") -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_IS_PD)
    keys = jax.random.split(key, len(leaves))
    dt = jnp.dtype(dtype)
    out = [_init_leaf(pd, k, dt) for pd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract(defs: Any, dtype: str = "bfloat16") -> Any:
    dt = jnp.dtype(dtype)

    def f(pd: PD):
        return jax.ShapeDtypeStruct(pd.shape, jnp.dtype(pd.dtype) if pd.dtype else dt)

    return jax.tree.map(f, defs, is_leaf=_IS_PD)


def specs(defs: Any, rules: Dict[str, Any]) -> Any:
    return jax.tree.map(lambda pd: sh.spec_for(pd.logical, rules, pd.shape),
                        defs, is_leaf=_IS_PD)


def count_params(defs: Any, active_only: bool = False) -> int:
    total = 0
    for pd in jax.tree.leaves(defs, is_leaf=_IS_PD):
        n = int(np.prod(pd.shape))
        total += n
    return total


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token: routed experts count as top_k (+ shared) of E."""
    defs = param_defs(cfg)
    total = 0
    for pd in jax.tree.leaves(defs, is_leaf=_IS_PD):
        n = int(np.prod(pd.shape))
        if "experts" in pd.logical and cfg.moe is not None:
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def trainable_mask(defs: Any) -> Any:
    """PEFT mask: True for adapter + head leaves (the paper's trainable set)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=_IS_PD)
    out = []
    for path, pd in flat:
        names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        is_tr = ("adapter" in names) or ("head" in names)
        out.append(is_tr)
    return jax.tree.unflatten(treedef, out)
