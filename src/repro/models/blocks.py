"""Forward computation for every block kind.

All block functions share the signature::

    new_h, new_cache, aux = apply_block(kind, cfg, params, h, ctx)

where ``ctx`` is a :class:`BlockCtx` carrying mode ("seq" for train/prefill over a full
sequence, "step" for single-token decode), positions, the per-layer cache slice, and
optional cross-attention memory. Shapes:

    h          [B, S, D]          (S == 1 in "step" mode)
    cache      per-kind dict, see repro.models.kvcache
    memory     [B, T_mem, D]      (VLM patches / audio frames / encoder output)

Attention is computed with a query-chunked scan so no [S, S] score tensor is ever
materialized (required for the 32k prefill shape), with optional sliding windows and
attention-sink slots (Hymba meta tokens).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.adapter import apply_adapter

Array = jax.Array


def _chunk_of(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------


@dataclass
class BlockCtx:
    cfg: ModelConfig
    mode: str                       # "seq" | "step"
    positions: Array                # [B, S] absolute token positions
    causal: bool = True
    memory: Optional[Array] = None  # [B, T_mem, D]
    cache_positions: Optional[Array] = None   # [B, Ck] positions held in cache
    write_slots: Optional[Array] = None       # [B, S] cache slots for new tokens
    impl: str = "jnp"               # "jnp" | "pallas"
    q_chunk: int = 1024
    remat: bool = False             # per-block activation checkpointing
    act_spec: Any = None            # PartitionSpec pinned on the residual stream
    moe_groups: int = 1             # GShard group-local dispatch groups


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def rmsnorm(p: Dict[str, Array], x: Array) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + 1e-6) * p["scale"]
    return out.astype(x.dtype)


def layernorm(p: Dict[str, Array], x: Array) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + 1e-5) * p["scale"] + p.get("bias", 0.0)
    return out.astype(x.dtype)


def norm(cfg: ModelConfig, p, x):
    return layernorm(p, x) if cfg.norm == "layernorm" else rmsnorm(p, x)


def _ffn_act(cfg: ModelConfig):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[cfg.activation]


def ffn(cfg: ModelConfig, p: Dict[str, Array], x: Array) -> Array:
    act = _ffn_act(cfg)
    if cfg.glu:
        return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_in"]
    if "b_in" in p:
        h = h + p["b_in"].astype(x.dtype)
    h = act(h)
    out = h @ p["w_out"]
    if "b_out" in p:
        out = out + p["b_out"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, hd]; positions: [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs          # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core: chunked-query attention against a (possibly cached) KV set
# ---------------------------------------------------------------------------


def _attend(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array, *,
            causal: bool, window: Optional[int], n_sink: int,
            q_chunk: int, score_spec=None) -> Array:
    """q [B,Sq,H,hd]; k,v [B,Sk,K,hd]; *_pos absolute positions ([B,S*]).

    Returns [B, Sq, H, hd]. Never materializes more than [B, H, q_chunk, Sk]
    scores; each q-chunk is rematerialized in the backward (flash-style — the
    fp32 score tensor is never a residual). ``score_spec`` (a PartitionSpec for
    [B, K, G, c, Sk]) sequence-shards the scores when heads don't divide the
    tensor axis (e.g. 40 heads on model=16). ``k_pos`` may contain -1 for
    unwritten cache slots.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, K, G, hd)

    def mask_for(qp, kp, k_slot):
        # qp [B, c] ; kp [B, Sk]
        m = kp[:, None, :] >= 0
        if causal:
            m &= kp[:, None, :] <= qp[:, :, None]
        if window is not None:
            in_win = (qp[:, :, None] - kp[:, None, :]) < window
            if n_sink > 0:
                in_win |= k_slot[None, None, :] < n_sink
            m &= in_win
        return m                                                     # [B, c, Sk]

    k_slot = jnp.arange(k.shape[1], dtype=jnp.int32)

    def chunk_fn(qc, qpc, k, v, k_pos):
        # qc [B, c, K, G, hd]
        s = jnp.einsum("bckgh,bskh->bkgcs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        if score_spec is not None:
            s = lax.with_sharding_constraint(s, score_spec)
        m = mask_for(qpc, k_pos, k_slot)                             # [B, c, Sk]
        s = jnp.where(m[:, None, None, :, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # fully-masked rows (e.g. pos<0 padding) -> zeros
        p = jnp.where(m[:, None, None, :, :], p, 0.0).astype(v.dtype)
        return jnp.einsum("bkgcs,bskh->bckgh", p, v)

    if Sq > 1:
        # flash-style: recompute scores in the backward instead of stashing
        # the [B, H, c, Sk] fp32 score / bool mask tensors per chunk.
        chunk_fn = jax.checkpoint(chunk_fn)

    if Sq <= q_chunk:
        out = chunk_fn(qg, q_pos, k, v, k_pos)
    else:
        assert Sq % q_chunk == 0, (Sq, q_chunk)
        nc = Sq // q_chunk
        qs = qg.reshape(B, nc, q_chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = q_pos.reshape(B, nc, q_chunk).transpose(1, 0, 2)
        out = lax.map(lambda args: chunk_fn(args[0], args[1], k, v, k_pos),
                      (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G, hd)
    return out.reshape(B, Sq, H, hd)


def attention(cfg: ModelConfig, p: Dict[str, Array], x: Array, ctx: BlockCtx,
              cache: Optional[Dict[str, Array]] = None,
              ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Self-attention with optional KV cache (decode) and sliding window."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, kk, vv = q + p["bq"], kk + p["bk"], vv + p["bv"]
    if cfg.rope:
        q = rope(q, ctx.positions, cfg.rope_theta)
        kk = rope(kk, ctx.positions, cfg.rope_theta)

    n_sink = 128 if any(kind == "hymba" for kind, _ in cfg.pattern) else 0
    new_cache = None

    def _quant(t):
        """Per-(token, head) int8 symmetric quantization: (q, scale)."""
        s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
        s = jnp.maximum(s, 1e-6) / 127.0
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / s), -127, 127
                     ).astype(jnp.int8)
        return q, s.astype(x.dtype)

    def _dequant(q, s):
        return q.astype(x.dtype) * s

    if cache is None:
        k_use, v_use, k_pos = kk, vv, ctx.positions
    elif ctx.mode == "prefill":
        # gather-fill: ctx.write_slots is [B, Ck] = prompt index landing in each
        # cache slot (deterministic; no duplicate scatter). Attention itself runs
        # against the full freshly-projected kk/vv.
        gi = ctx.write_slots[..., None, None]
        gk = jnp.take_along_axis(kk, gi, axis=1)
        gv = jnp.take_along_axis(vv, gi, axis=1)
        if cfg.kv_quant:
            qk, sk = _quant(gk)
            qv, sv = _quant(gv)
            new_cache = {"k": qk, "v": qv, "k_s": sk, "v_s": sv}
        else:
            new_cache = {"k": gk.astype(cache["k"].dtype),
                         "v": gv.astype(cache["v"].dtype)}
        k_use, v_use, k_pos = kk, vv, ctx.positions
    else:
        # decode step: scatter the single new token at ctx.write_slots ([B, 1])
        b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
        ws = ctx.write_slots
        if cfg.kv_quant:
            qk, sk = _quant(kk)
            qv, sv = _quant(vv)
            new_cache = {
                "k": cache["k"].at[b_idx, ws].set(qk),
                "v": cache["v"].at[b_idx, ws].set(qv),
                "k_s": cache["k_s"].at[b_idx, ws].set(sk),
                "v_s": cache["v_s"].at[b_idx, ws].set(sv),
            }
            k_use = _dequant(new_cache["k"], new_cache["k_s"])
            v_use = _dequant(new_cache["v"], new_cache["v_s"])
        else:
            ck = cache["k"].at[b_idx, ws].set(kk.astype(cache["k"].dtype))
            cv = cache["v"].at[b_idx, ws].set(vv.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            k_use, v_use = ck, cv
        k_pos = ctx.cache_positions

    score_spec = None
    if (ctx.act_spec is not None and len(ctx.act_spec) and S > 1
            and k_use.shape[1] % 16 == 0):
        from jax.sharding import PartitionSpec as P
        score_spec = P(ctx.act_spec[0], None, None, None, ctx.act_spec[-1])
    out = _attend(q, k_use, v_use, ctx.positions, k_pos,
                  causal=ctx.causal, window=cfg.sliding_window,
                  n_sink=n_sink, q_chunk=ctx.q_chunk, score_spec=score_spec)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


def cross_attention(cfg: ModelConfig, p: Dict[str, Array], x: Array,
                    ctx: BlockCtx, cache: Optional[Dict[str, Array]] = None,
                    ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Cross-attention against ctx.memory (or cached memory projections)."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cache is not None and "xk" in cache and ctx.memory is None:
        kk, vv = cache["xk"], cache["xv"]
        new_cache = cache
    else:
        mem = ctx.memory
        kk = jnp.einsum("btd,dhk->bthk", mem, p["wk"])
        vv = jnp.einsum("btd,dhk->bthk", mem, p["wv"])
        new_cache = {"xk": kk, "xv": vv} if cache is not None else None
    Tm = kk.shape[1]
    k_pos = jnp.zeros((B, Tm), dtype=jnp.int32)      # memory fully visible
    out = _attend(q, kk, vv, jnp.zeros_like(ctx.positions), k_pos,
                  causal=False, window=None, n_sink=0, q_chunk=ctx.q_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Mixture of Experts (scatter-dispatch, capacity-bounded — see DESIGN.md §4)
# ---------------------------------------------------------------------------


def _moe_dispatch_group(cfg: ModelConfig, p: Dict[str, Array], xt: Array,
                        C: int) -> Tuple[Array, Array, Array]:
    """Capacity-bounded dispatch for one token group (all ops group-local).

    xt: [Tg, D]. Returns (routed_out [Tg, D], me [E], pe [E]) where me/pe feed
    the load-balance loss.
    """
    m = cfg.moe
    Tg, D = xt.shape
    E, kk = m.n_experts, m.top_k

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)   # [Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = lax.top_k(probs, kk)                                  # [Tg, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # rank of each (token, choice) within its expert via sort (no [T,E] cumsum)
    flat_e = eidx.reshape(Tg * kk)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=flat_e.dtype))
    rank_sorted = jnp.arange(Tg * kk, dtype=jnp.int32) - grp_start[sorted_e]
    ranks = jnp.zeros(Tg * kk, jnp.int32).at[order].set(rank_sorted)

    keep = ranks < C
    slot = jnp.where(keep, flat_e * C + ranks, E * C)                   # dummy

    xr = jnp.repeat(xt, kk, axis=0)                                     # [Tg*k, D]
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].add(xr)
    xe = buf[: E * C].reshape(E, C, D)

    act = _ffn_act(cfg)
    hg = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"])
    hu = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    ye = jnp.einsum("ecf,efd->ecd", act(hg) * hu, p["we_down"])
    flat_out = jnp.concatenate(
        [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
    tok_out = flat_out[slot] * (gates.reshape(Tg * kk, 1).astype(ye.dtype)
                                * keep[:, None])
    routed = tok_out.reshape(Tg, kk, D).sum(axis=1)

    me = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1))
    pe = jnp.mean(probs, axis=0)
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return routed, me, pe, zl


def moe_ffn(cfg: ModelConfig, p: Dict[str, Array], x: Array,
            ctx: Optional["BlockCtx"] = None,
            ) -> Tuple[Array, Dict[str, Array]]:
    """x: [B, S, D] -> (out, aux losses).

    GShard-style *group-local* dispatch: tokens are split into
    ``ctx.moe_groups`` groups aligned with the data-parallel sharding, each
    group routes/scatters/combines locally (capacity per group), and only the
    expert einsums touch the expert-sharded weights. This keeps the dispatch
    buffers sharded [G('data'), E, C_g, D] with NO global scatter — the
    replicated [T*k*cf, D] buffer of the naive formulation (13+ GiB/chip at
    llama4 train_4k scale) never exists. See EXPERIMENTS.md §Perf.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, kk = m.n_experts, m.top_k
    G = 1
    if ctx is not None and getattr(ctx, "moe_groups", 1) > 1:
        G = ctx.moe_groups
        if T % G != 0:
            G = 1
    Tg = T // G
    C = int(math.ceil(Tg * kk / E * m.capacity_factor))
    C = max(8, -(-C // 8) * 8)

    xg = x.reshape(G, Tg, D)
    if G > 1 and ctx is not None and ctx.act_spec is not None:
        from jax.sharding import PartitionSpec as P
        xg = lax.with_sharding_constraint(xg, P(ctx.act_spec[0], None,
                                                ctx.act_spec[-1]))
    routed, me, pe, zl = jax.vmap(
        lambda xt: _moe_dispatch_group(cfg, p, xt, C))(xg)
    routed = routed.reshape(B, S, D)

    xt = x.reshape(T, D)
    act = _ffn_act(cfg)
    shared = (act(xt @ p["ws_gate"]) * (xt @ p["ws_up"])) @ p["ws_down"]
    out = routed + shared.reshape(B, S, D)

    aux = {
        "moe_aux": E * jnp.sum(me.mean(0) * pe.mean(0)) * m.router_aux_weight,
        "moe_z": jnp.mean(zl) * m.router_z_weight,
    }
    return out, aux


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) — chunked parallel wkv with data-dependent decay
# ---------------------------------------------------------------------------


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x[t-1] (zeros / cached `prev` at t=0). x: [B, S, D], prev: [B, D]."""
    if x.shape[1] == 1:
        base = jnp.zeros_like(x[:, 0]) if prev is None else prev
        return base[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _ddlerp(p, xx: Array, sx: Array) -> Tuple[Array, ...]:
    """RWKV6 data-dependent token-shift mixing -> (r,k,v,w,g) inputs."""
    base = xx + sx * p["mu"][0]
    lo = jnp.tanh(base @ p["tm_w1"]).reshape(*xx.shape[:-1], 5, 32)
    mws = jnp.einsum("bslr,lrd->bsld", lo, p["tm_w2"])                 # [B,S,5,D]
    outs = []
    for i in range(5):
        outs.append(xx + sx * (p["mu"][i] + mws[:, :, i].astype(xx.dtype)))
    return tuple(outs)


def _wkv_chunk(state: Array, r, k, v, lw, u):
    """One chunk of the RWKV6 recurrence (see DESIGN.md / kernels/rwkv_scan.py).

    state [N, hd, hd] fp32; r,k,v [N, L, hd]; lw = log decay (<=0) [N, L, hd].
    Returns (new_state, out [N, L, hd]).
    """
    N, L, hd = r.shape
    ca = jnp.cumsum(lw, axis=1)                     # inclusive log-decay prefix
    ca_prev = ca - lw                               # exclusive
    # inter-chunk: r_t decayed against incoming state
    inter = jnp.einsum("nlk,nkv->nlv", r * jnp.exp(ca_prev), state)
    # intra-chunk pairwise decays (all exponents <= 0: safe)
    diff = ca_prev[:, :, None, :] - ca[:, None, :, :]       # [N, L, L, hd]
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)[None, :, :, None]
    P = jnp.where(mask, jnp.exp(diff), 0.0)
    A = jnp.einsum("ntk,ntsk,nsk->nts", r, P, k)
    intra = jnp.einsum("nts,nsv->ntv", A, v)
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True) * v   # current-token bonus
    out = inter + intra + diag
    # state update
    decay_all = jnp.exp(ca[:, -1])                          # [N, hd]
    carry_k = k * jnp.exp(ca[:, -1][:, None, :] - ca)       # prod_{u>s} w
    new_state = decay_all[:, :, None] * state + jnp.einsum(
        "nsk,nsv->nkv", carry_k, v)
    return new_state, out


def rwkv_time_mix(cfg: ModelConfig, p, x: Array,
                  cache: Optional[Dict[str, Array]],
                  impl: str = "jnp",
                  ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    B, S, D = x.shape
    hd = cfg.ssm.head_dim
    H = D // hd
    prev = cache.get("px_tm") if cache else None
    xprev = _token_shift(x, prev)
    sx = xprev - x
    xr, xk, xv, xw, xg = _ddlerp(p, x, sx)

    r = (xr @ p["wr"].reshape(D, D)).reshape(B, S, H, hd)
    k = (xk @ p["wk"].reshape(D, D)).reshape(B, S, H, hd)
    v = (xv @ p["wv"].reshape(D, D)).reshape(B, S, H, hd)
    g = (xg @ p["wg"].reshape(D, D)).reshape(B, S, H, hd)
    dd = jnp.tanh(xw @ p["dd_w1"]) @ p["dd_w2"]                    # [B,S,D]
    wlog = p["decay_base"].reshape(1, 1, H, hd) + dd.reshape(B, S, H, hd)
    lw = -jnp.exp(wlog.astype(jnp.float32))                        # log decay <= 0
    u = p["bonus_u"].astype(jnp.float32)

    rf = r.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    lwf = lw.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    state0 = (cache["state"].reshape(B * H, hd, hd).astype(jnp.float32)
              if cache else jnp.zeros((B * H, hd, hd), jnp.float32))

    if impl == "pallas" and S > 1:
        from repro.kernels import ops
        out, state = ops.rwkv_scan(rf, kf, vf, lwf, uf, state0)
    elif S == 1:
        # single-step recurrence
        kv = jnp.einsum("nk,nv->nkv", kf[:, 0], vf[:, 0])
        out = (jnp.einsum("nk,nkv->nv", rf[:, 0], state0 + uf[:, 0, :, None] * kv)
               )[:, None, :]
        state = jnp.exp(lwf[:, 0])[:, :, None] * state0 + kv
    else:
        L = _chunk_of(S, 32)
        nchunks = S // L

        wkv = jax.checkpoint(_wkv_chunk)   # never stash the [L,L,hd] decays

        def body(st, idx):
            sl = lambda a: lax.dynamic_slice_in_dim(a, idx * L, L, axis=1)
            st2, out_c = wkv(st, sl(rf), sl(kf), sl(vf), sl(lwf),
                             uf[:, 0][:, None, :])
            return st2, out_c

        state, outs = lax.scan(body, state0, jnp.arange(nchunks))
        out = outs.transpose(1, 0, 2, 3).reshape(B * H, S, hd)

    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)            # [B,S,H,hd]
    # per-head group-norm, then gate
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * lax.rsqrt(var + 1e-5)
    out = out.reshape(B, S, D) * p["ln_x"]
    out = out * jax.nn.silu(g.astype(jnp.float32)).reshape(B, S, D)
    y = (out.astype(x.dtype).reshape(B, S, H, hd))
    y = jnp.einsum("bshk,hkd->bsd", y, p["wo"])

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["state"] = state.reshape(B, H, hd, hd).astype(cache["state"].dtype)
        new_cache["px_tm"] = x[:, -1]
    return y, new_cache


def rwkv_channel_mix(cfg: ModelConfig, p, x: Array,
                     cache: Optional[Dict[str, Array]],
                     ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    prev = cache.get("px_cm") if cache else None
    xprev = _token_shift(x, prev)
    sx = xprev - x
    xk = x + sx * p["mu_ck"]
    xr = x + sx * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["wk_c"]))
    v = k @ p["wv_c"]
    out = jax.nn.sigmoid((xr @ p["wr_c"]).astype(jnp.float32)).astype(x.dtype) * v
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["px_cm"] = x[:, -1]
    return out, new_cache


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba's parallel SSM heads)
# ---------------------------------------------------------------------------


def mamba_mix(cfg: ModelConfig, p, x: Array,
              cache: Optional[Dict[str, Array]],
              ) -> Tuple[Array, Optional[Dict[str, Array]]]:
    B, S, D = x.shape
    di = cfg.n_heads * cfg.head_dim
    N = cfg.ssm.state_size
    R = cfg.ssm.dt_rank
    W = cfg.ssm.conv_width

    xz = x @ p["in_proj"]                                           # [B,S,di]
    # causal depthwise conv
    prev = (cache.get("conv") if cache else None)
    if prev is None:
        prev = jnp.zeros((B, W - 1, di), xz.dtype)
    xc = jnp.concatenate([prev, xz], axis=1)                        # [B,S+W-1,di]
    idx = jnp.arange(S)[:, None] + jnp.arange(W)[None, :]           # [S,W]
    windows = xc[:, idx]                                            # [B,S,W,di]
    xconv = jnp.einsum("bswd,wd->bsd", windows, p["conv_w"])
    xs = jax.nn.silu(xconv)

    proj = xs @ p["x_proj"]                                         # [B,S,R+2N]
    dt_lr, Bmat, Cmat = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_lr @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                    # [di,N]
    Abar = jnp.exp(dt[..., None] * A)                               # [B,S,di,N]
    Bx = (dt[..., None] * Bmat[:, :, None, :].astype(jnp.float32)
          * xs[..., None].astype(jnp.float32))                      # [B,S,di,N]

    s0 = (cache["ssm"].astype(jnp.float32) if cache
          else jnp.zeros((B, di, N), jnp.float32))

    if S == 1:
        s1 = Abar[:, 0] * s0 + Bx[:, 0]
        ys = jnp.einsum("bdn,bn->bd", s1, Cmat[:, 0].astype(jnp.float32))[:, None]
        state = s1
    else:
        L = _chunk_of(S, 128)
        nch = S // L

        @jax.checkpoint
        def chunk(st, a, b, c):
            # associative scan within chunk: (a, b) composition
            def comb(x1, x2):
                return (x1[0] * x2[0], x2[0] * x1[1] + x2[1])
            aa, bb = lax.associative_scan(comb, (a, b), axis=1)
            states = aa * st[:, None] + bb                          # [B,L,di,N]
            y = jnp.einsum("bldn,bln->bld", states, c.astype(jnp.float32))
            return states[:, -1], y

        def body(st, idx):
            a = lax.dynamic_slice_in_dim(Abar, idx * L, L, axis=1)
            b = lax.dynamic_slice_in_dim(Bx, idx * L, L, axis=1)
            c = lax.dynamic_slice_in_dim(Cmat, idx * L, L, axis=1)
            return chunk(st, a, b, c)

        state, ys = lax.scan(body, s0, jnp.arange(nch))
        ys = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = ys.astype(x.dtype) + xs * p["d_skip"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["ssm"] = state.astype(cache["ssm"].dtype)
        new_cache["conv"] = xc[:, -(W - 1):] if W > 1 else cache["conv"]
    return y, new_cache


# ---------------------------------------------------------------------------
# Block dispatch
# ---------------------------------------------------------------------------

_ZERO_AUX = {"moe_aux": jnp.float32(0.0), "moe_z": jnp.float32(0.0)}


def apply_block(kind: str, cfg: ModelConfig, p: Dict[str, Any], h: Array,
                ctx: BlockCtx, cache: Optional[Dict[str, Array]] = None,
                ) -> Tuple[Array, Optional[Dict[str, Array]], Dict[str, Array]]:
    aux = dict(_ZERO_AUX)
    if kind in ("dense", "moe", "cross"):
        a, new_cache = attention(cfg, p["attn"], norm(cfg, p["ln1"], h), ctx, cache)
        h = h + a
        if kind == "cross":
            xa, xc = cross_attention(cfg, p["xattn"], norm(cfg, p["lnx"], h),
                                     ctx, cache)
            h = h + jnp.tanh(p["xgate"].astype(jnp.float32)).astype(h.dtype) * xa
            if new_cache is not None and xc is not None:
                new_cache = {**new_cache, **{k2: v2 for k2, v2 in xc.items()
                                             if k2 in ("xk", "xv")}}
        hn = norm(cfg, p["ln2"], h)
        if kind == "moe":
            f, moe_aux = moe_ffn(cfg, p["moe"], hn, ctx)
            aux = {k2: aux[k2] + moe_aux[k2] for k2 in aux}
        else:
            f = ffn(cfg, p["ffn"], hn)
        h = h + f
    elif kind == "rwkv":
        t, new_cache = rwkv_time_mix(cfg, p["rwkv"], norm(cfg, p["ln1"], h),
                                     cache, impl=ctx.impl)
        h = h + t
        c, new_cache2 = rwkv_channel_mix(cfg, p["rwkv"], norm(cfg, p["ln2"], h),
                                         new_cache)
        new_cache = new_cache2 if new_cache2 is not None else new_cache
        h = h + c
    elif kind == "hymba":
        hn = norm(cfg, p["ln1"], h)
        a, attn_cache = attention(cfg, p["attn"], hn, ctx, cache)
        s, ssm_cache = mamba_mix(cfg, p["ssm"], hn, cache)
        di = cfg.n_heads * cfg.head_dim

        def _rms(v, scale):
            vf = v.astype(jnp.float32)
            return (vf * lax.rsqrt(jnp.mean(vf * vf, -1, keepdims=True) + 1e-6)
                    * scale).astype(v.dtype)

        fused = 0.5 * (_rms(a, p["norm_attn"]) + _rms(s, p["norm_ssm"]))
        y = jnp.einsum("bshk,hkd->bsd",
                       fused.reshape(*fused.shape[:-1], cfg.n_heads, cfg.head_dim),
                       p["attn"]["wo"])
        h = h + y
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            if attn_cache:
                new_cache.update({k2: attn_cache[k2] for k2 in attn_cache
                                  if k2 in ("k", "v", "k_s", "v_s")})
            if ssm_cache:
                new_cache.update({k2: ssm_cache[k2] for k2 in ("ssm", "conv")})
        f = ffn(cfg, p["ffn"], norm(cfg, p["ln2"], h))
        h = h + f
    else:
        raise ValueError(kind)

    # ---- the paper's serial adapter, after the FFN/channel-mix sublayer ----
    h = apply_adapter(p["adapter"], h, activation=cfg.adapter.activation,
                      impl=ctx.impl)
    if ctx.act_spec is not None:
        h = lax.with_sharding_constraint(h, ctx.act_spec)
    return h, new_cache, aux
