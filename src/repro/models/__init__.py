"""repro.models"""
