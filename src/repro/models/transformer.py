"""Generic pattern-scanned transformer with RingAda's static unfreeze boundary.

The layer stack is organized as ``cfg.pattern`` (e.g. ``[(dense,4),(cross,1)]``)
repeated ``cfg.repeats`` times, with parameters stacked ``[R, C, ...]`` and executed
as an outer ``lax.scan`` over repeats and an inner scan over the pattern counts.

RingAda's *scheduled layer unfreezing* enters as the static ``boundary`` argument of
:func:`forward`: repeats ``[0, boundary)`` run inside ``lax.stop_gradient`` in their
own scan, so reverse-mode autodiff emits **no backward pass and saves no residuals**
for the frozen trunk — the exact compute/memory saving the paper's early-stopped
backpropagation provides, realized at the XLA level. (``boundary`` counts *frozen*
repeats from the bottom; unfreeze depth ``d`` maps to ``boundary = R - d``.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import kvcache
from repro.models.blocks import BlockCtx, apply_block, norm

Array = jax.Array

_ZERO_AUX = lambda: {"moe_aux": jnp.float32(0.0), "moe_z": jnp.float32(0.0)}


def pick_chunk(n: int, cap: int = 512) -> int:
    """Largest divisor of n that is <= cap (query-chunk / scan-chunk size)."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def n_meta(cfg: ModelConfig) -> int:
    return 128 if any(k == "hymba" for k, _ in cfg.pattern) else 0


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------


def _tree_slice(tree, lo: int, hi: int):
    return jax.tree.map(lambda x: x[lo:hi], tree)


def _run_repeats(cfg: ModelConfig, blocks, h: Array, aux, ctx: BlockCtx,
                 caches=None, pattern=None):
    """Scan over the (sliced) repeats axis of every pattern entry."""
    pattern = pattern or cfg.pattern
    R = jax.tree.leaves(blocks)[0].shape[0]
    if R == 0:
        return h, aux, caches

    has_cache = caches is not None

    def repeat_body(carry, xs):
        hh, ax = carry
        if has_cache:
            entry_params, entry_caches = xs
        else:
            entry_params, entry_caches = xs, [None] * len(pattern)
        new_caches = []
        for (kind, count), ep, ec in zip(pattern, entry_params, entry_caches):
            def block_core(p2, h2, cache2, kind=kind):
                return apply_block(kind, cfg, p2, h2, ctx, cache2)

            if ctx.remat and not has_cache:
                block_core = jax.checkpoint(block_core)

            def inner(c2, xs2, block_core=block_core):
                h2, ax2 = c2
                p2, cache2 = xs2 if has_cache else (xs2, None)
                h3, nc, a = block_core(p2, h2, cache2)
                ax3 = {k: ax2[k] + a[k] for k in ax2}
                return (h3, ax3), nc

            xs_inner = (ep, ec) if has_cache else ep
            (hh, ax), nc = lax.scan(inner, (hh, ax), xs_inner)
            new_caches.append(nc)
        return (hh, ax), tuple(new_caches) if has_cache else None

    xs = (blocks, caches) if has_cache else blocks
    (h, aux), ys = lax.scan(repeat_body, (h, aux), xs)
    return h, aux, (ys if has_cache else None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed(cfg: ModelConfig, params, tokens: Array, positions: Array) -> Array:
    h = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if not cfg.rope and "pos" in params["embed"]:
        pt = params["embed"]["pos"]
        h = h + jnp.take(pt, jnp.clip(positions, 0, pt.shape[0] - 1), axis=0)
    return h


def head(cfg: ModelConfig, params, h: Array) -> Array:
    h = norm(cfg, params["final_norm"], h)
    logits = h @ params["head"]["w"]
    if cfg.head_out is None and cfg.padded_vocab > cfg.vocab_size:
        # vocab is padded for even sharding; pad logits never win
        pad = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                        0.0, -1e30).astype(logits.dtype)
        logits = logits + pad
    return logits


# ---------------------------------------------------------------------------
# Encoder (seamless): non-causal dense stack over pre-embedded frames
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params, frames: Array, *, impl: str = "jnp",
           remat: bool = False, act_spec=None) -> Array:
    B, T, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    ctx = BlockCtx(cfg=cfg, mode="seq", positions=pos, causal=False, impl=impl,
                   q_chunk=pick_chunk(T), remat=remat, act_spec=act_spec)
    h, _, _ = _run_repeats(cfg, params["encoder"]["blocks"], frames, _ZERO_AUX(),
                           ctx, pattern=(("dense", 1),))
    return norm(cfg, params["encoder"]["final_norm"], h)


# ---------------------------------------------------------------------------
# Forward (train / eval over a full sequence)
# ---------------------------------------------------------------------------


def forward(params, tokens: Array, cfg: ModelConfig, *,
            memory: Optional[Array] = None,
            boundary: int = 0,
            impl: str = "jnp",
            remat: bool = False,
            act_spec=None,
            moe_groups: int = 1,
            hot_adapters: Optional[Tuple] = None,
            head_params: Optional[Dict[str, Array]] = None,
            ) -> Tuple[Array, Dict[str, Array]]:
    """Returns (logits [B, S, V], aux). ``boundary`` = frozen repeats (static).

    ``memory``: VLM patch embeddings / audio frames (enc-dec encodes them first).

    ``hot_adapters`` / ``head_params``: when training, the differentiated leaves
    are passed *separately* (already sliced ``[boundary:]``) rather than merged
    into ``params`` — slicing a concat of (frozen, hot) rows would make the
    frozen scan appear differentiable to JAX (concat JVP materializes zero
    tangents) and re-linearize the whole trunk, destroying the early-stop win.
    """
    B, S = tokens.shape
    nm = n_meta(cfg)
    if cfg.enc_dec:
        assert memory is not None, "enc-dec needs frontend frames"
        memory = encode(cfg, params, memory, impl=impl, remat=remat,
                        act_spec=act_spec)

    pos = jnp.broadcast_to(jnp.arange(nm + S, dtype=jnp.int32)[None], (B, nm + S))
    h = embed(cfg, params, tokens, pos[:, nm:] if nm else pos)
    if nm:
        meta = jnp.broadcast_to(params["meta"][None].astype(h.dtype),
                                (B, nm, cfg.d_model))
        h = jnp.concatenate([meta, h], axis=1)

    ctx = BlockCtx(cfg=cfg, mode="seq", positions=pos, causal=True, memory=memory,
                   impl=impl, q_chunk=pick_chunk(nm + S), remat=remat,
                   act_spec=act_spec, moe_groups=moe_groups)

    aux = _ZERO_AUX()
    blocks = params["blocks"]
    if boundary > 0:
        frozen = tuple(_tree_slice(e, 0, boundary) for e in blocks)
        frozen = lax.stop_gradient(frozen)
        h, aux, _ = _run_repeats(cfg, frozen, h, aux, ctx)
        # === RingAda early-stop point: no gradients flow below this line ===
        h = lax.stop_gradient(h)
        aux = jax.tree.map(lax.stop_gradient, aux)
    if boundary < cfg.repeats:
        hot = tuple(_tree_slice(e, boundary, cfg.repeats) for e in blocks)
        if hot_adapters is not None:
            hot = tuple({**e, "adapter": ha}
                        for e, ha in zip(hot, hot_adapters))
        h, aux, _ = _run_repeats(cfg, hot, h, aux, ctx)

    if nm:
        h = h[:, nm:]
    hp = {**params, "head": head_params} if head_params is not None else params
    logits = head(cfg, hp, h)
    return logits, aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def prefill(params, tokens: Array, cfg: ModelConfig, *,
            memory: Optional[Array] = None, seq_len: Optional[int] = None,
            impl: str = "jnp", act_spec=None, moe_groups: int = 1,
            ) -> Tuple[Array, Dict[str, Any]]:
    """Run the prompt, return (last-token logits [B, V], filled cache).

    ``seq_len``: total decode horizon the cache must support (>= prompt length).
    """
    B, S = tokens.shape
    nm = n_meta(cfg)
    seq_len = seq_len or (nm + S)
    if cfg.enc_dec:
        memory = encode(cfg, params, memory, impl=impl)
    mem_len = memory.shape[1] if memory is not None else 0

    cache = kvcache.init_cache(cfg, B, seq_len, mem_len=mem_len)
    pos = jnp.broadcast_to(jnp.arange(nm + S, dtype=jnp.int32)[None], (B, nm + S))
    h = embed(cfg, params, tokens, pos[:, nm:] if nm else pos)
    if nm:
        meta = jnp.broadcast_to(params["meta"][None].astype(h.dtype),
                                (B, nm, cfg.d_model))
        h = jnp.concatenate([meta, h], axis=1)

    # deterministic gather-fill slots: for each cache slot, the last prompt
    # position that lands in it (ring buffer), or -1 if unwritten.
    ck = kvcache.cache_len(cfg, seq_len)
    ns = kvcache.n_sink(cfg)
    Sp = nm + S
    if cfg.sliding_window is None or ck >= seq_len:
        assert Sp <= ck, (f"prompt ({Sp} incl. meta) exceeds cache horizon "
                          f"({ck}); raise seq_len")
    slots = jnp.arange(ck, dtype=jnp.int32)
    if cfg.sliding_window is not None and ck < seq_len:
        w = ck - ns
        cand = jnp.where(slots < ns, slots,
                         slots + w * (jnp.maximum(Sp - 1 - slots, 0) // w))
    else:
        cand = slots
    fill_pos = jnp.where(cand < Sp, cand, -1)                      # [ck]
    cache["pos"] = jnp.broadcast_to(fill_pos[None], (B, ck))
    cache["next"] = jnp.full((B,), Sp, jnp.int32)

    ctx = BlockCtx(cfg=cfg, mode="prefill", positions=pos, causal=True,
                   memory=memory, impl=impl, q_chunk=pick_chunk(Sp),
                   act_spec=act_spec, moe_groups=moe_groups,
                   cache_positions=jnp.broadcast_to(fill_pos[None], (B, ck)),
                   write_slots=None)
    # prefill uses gather-fill: attention sees the full kk/vv it just computed and
    # the cache is written from ``fill_pos`` gathers (no duplicate-scatter).
    ctx.write_slots = jnp.where(fill_pos < 0, 0, fill_pos)[None].repeat(B, 0)

    aux = _ZERO_AUX()
    h, aux, new_layer_caches = _run_prefill(cfg, params["blocks"], h, aux, ctx,
                                            cache["layers"], fill_pos)
    cache["layers"] = new_layer_caches
    logits = head(cfg, params, h[:, -1:])[:, 0]
    return logits, cache


def _run_prefill(cfg, blocks, h, aux, ctx: BlockCtx, caches, fill_pos):
    """Prefill = seq-mode forward + cache construction via gathers."""
    # Run blocks in "prefill" mode: attention computes over its freshly-projected
    # kk/vv, then gathers rows at ``fill_pos`` into the cache (see blocks.attention
    # handling below via mode). We emulate by running each layer with cache and
    # mode="prefill"; blocks check ctx.mode.
    ctx2 = dataclasses.replace(ctx, mode="prefill")
    return _run_repeats(cfg, blocks, h, aux, ctx2, caches=caches)


def decode_step(params, token: Array, cache: Dict[str, Any], cfg: ModelConfig,
                *, impl: str = "jnp", act_spec=None
                ) -> Tuple[Array, Dict[str, Any]]:
    """One decode step. token [B, 1] int32. Returns (logits [B, V], new cache)."""
    B = token.shape[0]
    pos = cache["next"][:, None]                                    # [B, 1]
    h = embed(cfg, params, token, pos)

    ck = cache["pos"].shape[1]
    seq_len_equiv = ck if cfg.sliding_window is None else cfg.max_seq_len
    slot = kvcache.write_slot(cfg, pos, seq_len_equiv)
    slot = jnp.minimum(slot, ck - 1)
    new_pos_arr = cache["pos"].at[jnp.arange(B)[:, None], slot].set(pos)

    ctx = BlockCtx(cfg=cfg, mode="step", positions=pos, causal=True,
                   memory=None, impl=impl, q_chunk=1, act_spec=act_spec,
                   cache_positions=new_pos_arr, write_slots=slot)
    aux = _ZERO_AUX()
    h, aux, new_layer_caches = _run_repeats(cfg, params["blocks"], h, aux, ctx,
                                            caches=cache["layers"])
    logits = head(cfg, params, h)[:, 0]
    new_cache = {"layers": new_layer_caches, "pos": new_pos_arr,
                 "next": cache["next"] + 1}
    return logits, new_cache
