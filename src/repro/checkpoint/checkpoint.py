"""Checkpointing: flat-key .npz payloads + JSON metadata, sharding-aware restore.

PEFT-aware: ``adapters_only=True`` stores just the trainable set (adapters +
head + step), which is what RingAda clients would persist/exchange — a few MB even
for a 7B backbone.

Optimizer state rides along: pass ``opt_state=`` to :func:`save` and it is
stored under a reserved ``opt::`` key namespace (NEVER filtered by
``adapters_only`` — the moments exist only for the trainable set, so they are
part of the minimal resumable state, and dropping them silently would make a
"resumed" run diverge from the uninterrupted one).  :func:`restore_opt` is the
inverse.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def _key_filter(key: str, adapters_only: bool) -> bool:
    if not adapters_only:
        return True
    return ("adapter" in key.split(SEP)) or key.startswith("head")


OPT_NS = "opt"       # reserved top-level namespace for optimizer-state keys


def save(path: str, params: Any, *, step: int = 0, extra: Optional[Dict] = None,
         adapters_only: bool = False, opt_state: Any = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: v for k, v in _flatten(params).items()
            if _key_filter(k, adapters_only)}
    if opt_state is not None:
        # opt state is exempt from the adapters_only filter: the moments only
        # cover the trainable set in the first place, and a checkpoint without
        # them cannot resume bit-identically.
        flat.update({OPT_NS + SEP + k: v
                     for k, v in _flatten(opt_state).items()})
    # bfloat16 isn't npz-native: store raw uint16 + dtype tag
    payload, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            payload[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            payload[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(path + ".npz", **payload)
    meta = {"step": step, "dtypes": dtypes, "adapters_only": adapters_only,
            "has_opt_state": opt_state is not None, "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Any, *, mesh=None, specs: Any = None,
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like``; missing keys keep ``like`` values.

    With (mesh, specs) the restored leaves are device_put with their
    NamedSharding — restores shard directly onto production meshes.
    """
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    return _restore_into(like, data, meta, prefix="", mesh=mesh,
                         specs=specs), meta


def restore_opt(path: str, opt_like: Any) -> Any:
    """Restore the optimizer state saved via ``save(..., opt_state=...)``.

    ``opt_like`` supplies the pytree structure + leaf shapes (e.g. a freshly
    ``adamw.init``-ed state).  Raises if the checkpoint carries no opt state,
    and raises on ANY ``opt_like`` leaf missing from the payload (strict —
    unlike the params path there is no legitimate "reconstruct from seed"
    fallback for moments): a silently part-restored optimizer is exactly the
    resume-divergence bug this API exists to prevent.
    """
    with open(path + ".json") as f:
        meta = json.load(f)
    if not meta.get("has_opt_state"):
        raise ValueError(
            f"checkpoint {path!r} has no optimizer state (saved before the "
            f"opt round-trip existed, or with opt_state=None) — resuming "
            f"from it would silently reset the Adam moments")
    data = np.load(path + ".npz")
    return _restore_into(opt_like, data, meta, prefix=OPT_NS + SEP,
                         strict=True)


def _restore_into(like: Any, data, meta: Dict, *, prefix: str = "",
                  mesh=None, specs: Any = None, strict: bool = False) -> Any:
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    spec_leaves = (jax.tree.leaves(specs, is_leaf=lambda s: s is None or
                                   hasattr(s, "__len__") or True)
                   if specs is not None else None)

    out = []
    for i, (pathk, leaf) in enumerate(flat_like):
        key = prefix + SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                                for p in pathk)
        if key in data.files:
            arr = data[key]
            if meta["dtypes"].get(key) == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            arr = arr.reshape(np.shape(leaf))
            if mesh is not None and specs is not None:
                from jax.sharding import NamedSharding
                arr = jax.device_put(arr, NamedSharding(mesh, spec_leaves[i]))
            else:
                arr = jnp.asarray(arr)
            out.append(arr)
        else:
            if strict:
                # the missing-key fallback is only correct for the
                # adapters_only params path (frozen leaves reconstruct from
                # the seed); optimizer moments silently reset to the live
                # values would make a "resumed" run diverge without error
                raise KeyError(
                    f"checkpoint is missing key {key!r} for the requested "
                    f"tree (layout mismatch between the checkpoint and this "
                    f"session — different adamw/backend structure?)")
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)
