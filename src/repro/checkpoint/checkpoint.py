"""Checkpointing: flat-key .npz payloads + JSON metadata, sharding-aware restore.

PEFT-aware: ``save_adapters_only=True`` stores just the trainable set (adapters +
head + step), which is what RingAda clients would persist/exchange — a few MB even
for a 7B backbone.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def _key_filter(key: str, adapters_only: bool) -> bool:
    if not adapters_only:
        return True
    return ("adapter" in key.split(SEP)) or key.startswith("head")


def save(path: str, params: Any, *, step: int = 0, extra: Optional[Dict] = None,
         adapters_only: bool = False) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: v for k, v in _flatten(params).items()
            if _key_filter(k, adapters_only)}
    # bfloat16 isn't npz-native: store raw uint16 + dtype tag
    payload, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            payload[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            payload[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(path + ".npz", **payload)
    meta = {"step": step, "dtypes": dtypes, "adapters_only": adapters_only,
            "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Any, *, mesh=None, specs: Any = None,
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like``; missing keys keep ``like`` values.

    With (mesh, specs) the restored leaves are device_put with their
    NamedSharding — restores shard directly onto production meshes.
    """
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    spec_leaves = (jax.tree.leaves(specs, is_leaf=lambda s: s is None or
                                   hasattr(s, "__len__") or True)
                   if specs is not None else None)

    out = []
    for i, (pathk, leaf) in enumerate(flat_like):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        if key in data.files:
            arr = data[key]
            if meta["dtypes"].get(key) == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            arr = arr.reshape(np.shape(leaf))
            if mesh is not None and specs is not None:
                from jax.sharding import NamedSharding
                arr = jax.device_put(arr, NamedSharding(mesh, spec_leaves[i]))
            else:
                arr = jnp.asarray(arr)
            out.append(arr)
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out), meta
