"""repro.checkpoint"""
