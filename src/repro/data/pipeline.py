"""Data pipeline: per-client tokenized shards (synthetic, deterministic, offline).

RingAda's setting is U clients with private local datasets D_u. The pipeline
produces deterministic synthetic corpora per client with *client-specific
distributions* (distinct n-gram transition tables), so collaborative fine-tuning
across clients is actually measurable (a model fit to one client's distribution
has higher loss on the others).

Two task flavours:
  * ``lm``    — next-token prediction (labels = tokens shifted by 1)
  * ``qa``    — SQuAD-like span extraction for the mBERT config: the "document"
                contains a marked answer span; labels are (start, end).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class ClientDataset:
    client_id: int
    tokens: np.ndarray              # [N, seq] int32
    labels: np.ndarray              # [N, seq] int32 (lm) or [N, 2] (qa)
    kind: str = "lm"

    def __len__(self):
        return self.tokens.shape[0]


def _markov_corpus(rng: np.random.Generator, vocab: int, n: int, seq: int,
                   order_bias: float) -> np.ndarray:
    """Client-specific bigram process: next ~ (cur * a + b) mod vocab + noise."""
    a = int(rng.integers(3, 23)) * 2 + 1
    b = int(rng.integers(1, vocab - 1))
    toks = np.empty((n, seq), np.int32)
    cur = rng.integers(0, vocab, size=n)
    for t in range(seq):
        toks[:, t] = cur
        noise = rng.random(n) < order_bias
        nxt = (cur * a + b) % vocab
        cur = np.where(noise, rng.integers(0, vocab, size=n), nxt)
    return toks


def make_client_datasets(n_clients: int, *, vocab: int, n_per_client: int,
                         seq: int, seed: int = 0, kind: str = "lm",
                         ) -> List[ClientDataset]:
    out = []
    for u in range(n_clients):
        rng = np.random.default_rng(seed * 1000 + u)
        toks = _markov_corpus(rng, vocab, n_per_client, seq + 1, 0.15)
        if kind == "lm":
            ds = ClientDataset(u, toks[:, :-1].astype(np.int32),
                               toks[:, 1:].astype(np.int32), "lm")
        elif kind == "qa":
            # answer span marked by sentinel tokens; labels = span indices
            toks2 = toks[:, :seq].copy()
            starts = rng.integers(1, seq - 8, size=n_per_client)
            lens = rng.integers(1, 6, size=n_per_client)
            ends = np.minimum(starts + lens, seq - 2)
            sent = vocab - 1
            for i in range(n_per_client):
                toks2[i, starts[i] - 1] = sent       # answer-begin marker
                toks2[i, ends[i] + 1] = sent - 1     # answer-end marker
            ds = ClientDataset(u, toks2.astype(np.int32),
                               np.stack([starts, ends], -1).astype(np.int32),
                               "qa")
        else:
            raise ValueError(kind)
        out.append(ds)
    return out


class RingBatcher:
    """Yields [S, M, mb, seq] stacked per-client microbatches for ring rounds.

    Two sampling modes:

      * ``next()`` — fresh random draw every call (streaming-style; no batch
        identity across rounds).
      * ``next_slot()`` (requires ``slots_per_epoch``) — the epoch is a fixed
        cycle of ``slots_per_epoch`` batch *slots*; the slot -> example
        mapping is drawn ONCE from ``seed`` at construction and reused every
        epoch, so slot ``i`` holds bit-identical tokens/labels in epoch 0, 1,
        2, ...  This determinism is the activation cache's key contract
        (``core/actcache.py``): ``(slot, boundary)`` identifies the frozen
        trunk's inputs exactly.  Same seed => same mapping, across epochs and
        across re-instantiation.
    """

    def __init__(self, datasets: List[ClientDataset], n_micro: int,
                 micro_batch: int, seed: int = 0,
                 slots_per_epoch: Optional[int] = None):
        self.ds = datasets
        self.M, self.mb = n_micro, micro_batch
        self.rng = np.random.default_rng(seed)
        self.slots_per_epoch = slots_per_epoch
        self._t = 0
        # keyed by slot (not an ordered list): the cursor may start mid-epoch,
        # e.g. after a checkpoint restore, so slot 1 can be visited first
        self._slot_batches: Dict[int, Tuple[Array, Array]] = {}
        if slots_per_epoch is not None:
            if slots_per_epoch < 1:
                raise ValueError(f"slots_per_epoch must be >= 1, "
                                 f"got {slots_per_epoch}")
            # one dedicated generator so next() draws don't perturb the mapping
            srng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
            n = self.M * self.mb
            self._slot_idx = [
                [srng.integers(0, len(d), size=n) for d in datasets]
                for _ in range(slots_per_epoch)]

    def _stack(self, idx_per_ds) -> Tuple[Array, Array]:
        toks, labs = [], []
        for d, idx in zip(self.ds, idx_per_ds):
            toks.append(d.tokens[idx].reshape(self.M, self.mb, -1))
            labs.append(d.labels[idx].reshape(self.M, self.mb, -1))
        return (jnp.asarray(np.stack(toks)), jnp.asarray(np.stack(labs)))

    def next(self) -> Tuple[Array, Array]:
        idx = [self.rng.integers(0, len(d), size=self.M * self.mb)
               for d in self.ds]
        return self._stack(idx)

    def next_slot(self) -> Tuple[int, Array, Array]:
        """(slot, tokens, labels) — cycles slots 0..slots_per_epoch-1 forever.

        Batches are materialized on device once per slot and reused every
        epoch (they are identical by construction), so steady-state epochs do
        zero host-side batch assembly.
        """
        if self.slots_per_epoch is None:
            raise ValueError("RingBatcher built without slots_per_epoch; "
                             "use next() or pass slots_per_epoch")
        slot = self._t % self.slots_per_epoch
        self._t += 1
        if slot not in self._slot_batches:
            self._slot_batches[slot] = self._stack(self._slot_idx[slot])
        toks, labs = self._slot_batches[slot]
        return slot, toks, labs

    @property
    def epoch(self) -> int:
        return (0 if self.slots_per_epoch is None
                else self._t // self.slots_per_epoch)


class Batcher:
    """Flat [B, seq] batches for the single-device / pjit paths."""

    def __init__(self, dataset: ClientDataset, batch: int, seed: int = 0):
        self.d, self.B = dataset, batch
        self.rng = np.random.default_rng(seed)

    def next(self) -> Dict[str, Array]:
        idx = self.rng.integers(0, len(self.d), size=self.B)
        out = {"tokens": jnp.asarray(self.d.tokens[idx])}
        if self.d.kind == "lm":
            out["labels"] = jnp.asarray(self.d.labels[idx])
        else:
            lab = self.d.labels[idx]
            out["starts"] = jnp.asarray(lab[:, 0])
            out["ends"] = jnp.asarray(lab[:, 1])
        return out


def merged(datasets: List[ClientDataset]) -> ClientDataset:
    return ClientDataset(-1,
                         np.concatenate([d.tokens for d in datasets]),
                         np.concatenate([d.labels for d in datasets]),
                         datasets[0].kind)
