"""repro.data"""
