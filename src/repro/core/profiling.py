"""Per-layer fwd/bwd profiling — fills the simulator's lookup table.

The paper: "We profile the computation time of forward and backward propagation on
different edge devices by scaling the computational speed ... recorded in a lookup
table." Same here: one real measurement per block kind on this host, scaled by each
DeviceProfile.compute_speed.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.simulator import LayerProfile
from repro.models import params as prm
from repro.models.blocks import BlockCtx, apply_block


def _time(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def profile_layers(cfg: ModelConfig, batch: int, seq: int,
                   key=None) -> List[LayerProfile]:
    """Measure one block's fwd and fwd+bwd time; emit a per-layer lookup table."""
    key = key or jax.random.key(0)
    kind = cfg.pattern[0][0]
    defs = prm.block_defs(cfg, kind)
    p = prm.materialize(defs, key)
    h = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    ctx = BlockCtx(cfg=cfg, mode="seq", positions=pos, q_chunk=min(seq, 512))

    fwd = jax.jit(lambda pp, hh: apply_block(kind, cfg, pp, hh, ctx, None)[0])

    def loss(hh, ad, pp):
        out = apply_block(kind, cfg, {**pp, "adapter": ad}, hh, ctx, None)[0]
        return jnp.sum(out.astype(jnp.float32))

    # backward = dgrad chain through the block (the cotangent every unfrozen
    # stage must relay along the ring) + adapter wgrad
    fwdbwd = jax.jit(lambda pp, hh: jax.grad(loss, argnums=(0, 1))(
        hh, pp["adapter"], pp))

    t_f = _time(fwd, p, h)
    t_fb = _time(fwdbwd, p, h)
    t_b = max(t_fb - t_f, 0.3 * t_f)

    dt = jnp.dtype(jnp.bfloat16).itemsize
    w_mb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p)) / 1e6
    ad_mb = sum(x.size * x.dtype.itemsize
                for x in jax.tree.leaves(p["adapter"])) / 1e6
    act_mb = batch * seq * cfg.d_model * dt * 6 / 1e6   # ~residual set per block
    bnd_mb = batch * seq * cfg.d_model * dt / 1e6

    lp = LayerProfile(fwd_s=t_f, bwd_s=t_b, act_mb=act_mb,
                      weight_mb=w_mb - ad_mb, adapter_mb=ad_mb,
                      boundary_mb=bnd_mb)
    return [lp] * cfg.n_layers


def head_times(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, float]:
    key = jax.random.key(1)
    out_dim = cfg.out_dim            # e.g. 2 for the paper's SQuAD span head
    w = jax.random.normal(key, (cfg.d_model, out_dim), jnp.bfloat16) * 0.02
    h = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.bfloat16)
    fwd = jax.jit(lambda ww, hh: hh @ ww)
    g = jax.jit(lambda ww, hh: jax.grad(
        lambda w2: jnp.sum((hh @ w2).astype(jnp.float32)))(ww))
    t_f = _time(fwd, w, h)
    t_b = _time(g, w, h)
    dt = 2
    return {"head_fwd_s": t_f, "head_bwd_s": t_b,
            "head_mb": cfg.d_model * out_dim * dt / 1e6,
            "embed_mb": cfg.vocab_size * cfg.d_model * dt / 1e6}
