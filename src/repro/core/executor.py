"""RingExecutor: the fused end-to-end ring training step.

One donated, jitted executable per unfreeze boundary runs a FULL RingAda round
— all S owner-iterations (forward, early-stopped backward, stage-masked AdamW
on the adapters, replicated AdamW on the head) — entirely on device:

  * the owner rotation is a ``lax.scan`` over owners *inside* the executable;
    the owner-dependent hops use ``pipeline.ring_round_local``'s dynamic
    permutes so owner can be traced (the reference ``RingTrainer`` instead
    compiles one executable per (owner, boundary) pair: S x boundaries),
  * the optimizer is ``optim.adamw.tree_update`` with a stage mask
    ``stage >= F`` — frozen stages' adapters AND their Adam moments are
    bit-identical before and after the round,
  * params + optimizer moments are donated (``donate_argnums``), so the round
    updates in place instead of holding two copies live,
  * nothing syncs to the host: ``round()`` returns device arrays; callers
    ``float()`` them once per logging interval (async dispatch).

Numerics match ``RingTrainer`` exactly (same ``adamw.leaf_update`` math,
constant lr, no bias correction) — asserted by tests/test_executor.py.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pipeline as pl
from repro.core.unfreeze import UnfreezeSchedule, depth_to_boundary
from repro.optim import adamw

Array = jax.Array


def ring_opt_init(stage_blocks: Dict[str, Any], shared: Dict[str, Any]
                  ) -> Dict[str, Any]:
    """Ring optimizer state: adapter moments stage-stacked [S, lps, ...]
    (sharded with the adapters — optimizer state never crosses the ring, like
    the paper), head moments replicated."""
    m_ad, v_ad = adamw.init_moments(stage_blocks["adapter"])
    m_hd, v_hd = adamw.init_moments(shared["head"])
    return {"m": {"adapter": m_ad, "head": m_hd},
            "v": {"adapter": v_ad, "head": v_hd},
            "count": jnp.zeros((), jnp.int32)}


def ring_opt_specs() -> Dict[str, Any]:
    """PartitionSpec tree matching ``ring_opt_init``'s structure."""
    return {"m": {"adapter": P("stage"), "head": P()},
            "v": {"adapter": P("stage"), "head": P()},
            "count": P()}


def make_fused_round(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh, *,
                     n_stages: int, boundary: int, n_micro: int,
                     on_trace=None):
    """Build the fused round:

      fn(stage_blocks, shared, opt_state, tokens, labels)
        -> (stage_blocks, shared, opt_state, losses[S])

    Static per build: boundary only.  ``on_trace`` (if given) is called each
    time the function body is traced — i.e. once per XLA compilation — which is
    how tests count executables.  Wrap the result in
    ``jax.jit(..., donate_argnums=(0, 1, 2))`` (RingExecutor does).
    """
    S = n_stages
    lps = cfg.repeats // S
    assert boundary % lps == 0, f"boundary {boundary} not stage-aligned"
    F = boundary // lps
    local_round = pl.ring_round_local(cfg, n_stages=S, boundary=boundary,
                                      n_micro=n_micro)
    lr = jnp.float32(tc.learning_rate)

    def fused(stage_blocks, shared, opt_state, tokens, labels):
        # Local (per-shard) views: stage-sharded leaves arrive as [1, lps, ...].
        if on_trace is not None:
            on_trace()
        s = lax.axis_index("stage")
        hot = (s >= F).astype(jnp.float32)            # stage mask (terminator)
        my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)
        my_tokens, my_labels = tokens[0], labels[0]
        backbone = {k: v for k, v in my_blocks.items() if k != "adapter"}
        shared_rest = {k: v for k, v in shared.items() if k != "head"}
        unstage = lambda t: jax.tree.map(lambda x: x[0], t)
        restage = lambda t: jax.tree.map(lambda x: x[None], t)

        # Embeddings are round-constant (outside the trainable set): embed +
        # gather once, not once per owner-iteration.
        seq = my_tokens.shape[2]
        mb = my_tokens.shape[1]
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (mb, seq))
        emb_g = pl.gather_embeddings(cfg, shared_rest, my_tokens, pos)

        def owner_iter(carry, owner):
            ad, head, m_ad, v_ad, m_hd, v_hd = carry

            def local_loss(ad_, head_):
                return local_round(owner, {**backbone, "adapter": ad_},
                                   {**shared_rest, "head": head_},
                                   emb_g, my_labels)

            l_loc, (g_ad, g_hd) = jax.value_and_grad(
                local_loss, argnums=(0, 1))(ad, head)
            # head grads live only on the owner stage; psum replicates them
            # (same semantics as differentiating a replicated P() input).
            g_hd = jax.tree.map(lambda g: lax.psum(g, "stage"), g_hd)
            ad2, m_ad2, v_ad2 = adamw.tree_update(
                g_ad, m_ad, v_ad, ad, tc, lr=lr, mask=hot)
            head2, m_hd2, v_hd2 = adamw.tree_update(
                g_hd, m_hd, v_hd, head, tc, lr=lr)
            return (ad2, head2, m_ad2, v_ad2, m_hd2, v_hd2), l_loc

        init = (my_blocks["adapter"], shared["head"],
                unstage(opt_state["m"]["adapter"]), unstage(opt_state["v"]["adapter"]),
                opt_state["m"]["head"], opt_state["v"]["head"])
        (ad, head, m_ad, v_ad, m_hd, v_hd), local_losses = lax.scan(
            owner_iter, init, jnp.arange(S))
        # each iteration's loss lives only on its owner stage; one vector psum
        # per round replicates all S of them at once.
        losses = lax.psum(local_losses, "stage")
        mean_loss = jnp.mean(losses)

        new_blocks = {**stage_blocks, "adapter": restage(ad)}
        new_shared = {**shared, "head": head}
        new_opt = {"m": {"adapter": restage(m_ad), "head": m_hd},
                   "v": {"adapter": restage(v_ad), "head": v_hd},
                   "count": opt_state["count"] + S}
        return new_blocks, new_shared, new_opt, (losses, mean_loss)

    opt_spec = ring_opt_specs()
    return compat.shard_map(
        fused, mesh=mesh,
        in_specs=(P("stage"), P(), opt_spec, P("stage"), P("stage")),
        out_specs=(P("stage"), P(), opt_spec, (P(), P())))


class RingExecutor:
    """Collaborative fine-tuning over a ring of ``n_stages`` devices — fused.

    Drop-in upgrade of ``core/ring.py``'s ``RingTrainer``: same constructor,
    same ``round(tokens, labels)`` / ``export_params()`` surface, but each
    round is ONE donated executable instead of S dispatches + a host-side
    optimizer loop, and ``round()`` never blocks on the host (metrics are
    device arrays; see ``materialize_metrics``).

    The unfreeze boundary is evaluated once per round (at the round's first
    step).  When ``tc.unfreeze_interval`` is a multiple of ``n_stages`` this is
    identical to the reference trainer's per-iteration evaluation; otherwise a
    mid-round bump is deferred to the next round boundary.
    """

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                 params: Dict[str, Any], n_stages: int, n_micro: int, *,
                 donate: bool = True):
        assert len(cfg.pattern) == 1, "ring executor needs a uniform pattern"
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.S, self.M = n_stages, n_micro
        self.lps = cfg.repeats // n_stages
        self.stage_blocks, self.shared = pl.stage_stack(params, cfg, n_stages)
        self._params_rest = {k: v for k, v in params.items()
                             if k not in ("blocks",)}
        self.opt_state = ring_opt_init(self.stage_blocks, self.shared)
        self.sched = UnfreezeSchedule.from_train_config(tc)
        self.donate = donate
        self._fns: Dict[int, Any] = {}            # boundary -> jitted fused fn
        self.trace_counts: Dict[int, int] = {}    # boundary -> #compilations
        self.step = 0

    # ------------------------------------------------------------------
    def boundary_at(self, step: int) -> int:
        depth = self.sched.depth_at(step, self.cfg.n_layers)
        b = depth_to_boundary(self.cfg, depth)
        return (b // self.lps) * self.lps          # stage-aligned (terminator)

    def _fn(self, boundary: int):
        if boundary not in self._fns:
            self.trace_counts.setdefault(boundary, 0)

            def bump(b=boundary):
                self.trace_counts[b] += 1

            fused = make_fused_round(self.cfg, self.tc, self.mesh,
                                     n_stages=self.S, boundary=boundary,
                                     n_micro=self.M, on_trace=bump)
            donate = (0, 1, 2) if self.donate else ()
            self._fns[boundary] = jax.jit(fused, donate_argnums=donate)
        return self._fns[boundary]

    @property
    def n_executables(self) -> int:
        return len(self._fns)

    # ------------------------------------------------------------------
    def round(self, tokens: Array, labels: Array) -> Dict[str, Any]:
        """One training round: every client acts as initiator once.

        tokens/labels: [S, M, mb, seq] per-client local data for this round.
        Returns metrics as DEVICE arrays — no host sync.  Use
        ``materialize_metrics`` (or ``float()``) at your logging interval.
        """
        boundary = self.boundary_at(self.step)
        fn = self._fn(boundary)
        (self.stage_blocks, self.shared, self.opt_state,
         (losses, mean_loss)) = fn(
            self.stage_blocks, self.shared, self.opt_state, tokens, labels)
        self.step += self.S
        return {"loss": mean_loss, "losses": losses,
                "boundary": boundary, "step": self.step}

    @staticmethod
    def materialize_metrics(m: Dict[str, Any]) -> Dict[str, Any]:
        """Host-sync a metrics dict (the once-per-logging-interval sync)."""
        out: Dict[str, Any] = {}
        for k, v in m.items():
            if isinstance(v, jax.Array) and v.ndim == 0:
                out[k] = float(v)
            elif isinstance(v, jax.Array):
                out[k] = [float(x) for x in v]
            else:
                out[k] = v
        return out

    # ------------------------------------------------------------------
    def export_params(self) -> Dict[str, Any]:
        return pl.unstack(self.stage_blocks, self.cfg, self._params_rest,
                          self.shared)
