"""RingExecutor: the fused end-to-end ring training step.

One donated, jitted executable per unfreeze boundary runs a FULL RingAda round
— all S owner-iterations (forward, early-stopped backward, stage-masked AdamW
on the adapters, replicated AdamW on the head) — entirely on device:

  * the owner rotation is a ``lax.scan`` over owners *inside* the executable;
    the owner-dependent hops use ``pipeline.ring_round_local``'s dynamic
    permutes so owner can be traced (the reference ``RingTrainer`` instead
    compiles one executable per (owner, boundary) pair: S x boundaries),
  * the optimizer is ``optim.adamw.tree_update`` with a stage mask
    ``stage >= F`` — frozen stages' adapters AND their Adam moments are
    bit-identical before and after the round,
  * params + optimizer moments are donated (``donate_argnums``), so the round
    updates in place instead of holding two copies live,
  * nothing syncs to the host: ``round()`` returns device arrays; callers
    ``float()`` them once per logging interval (async dispatch).

Packed-conveyor Phase A (``packed=True``, the default): instead of re-running
a ``M + F - 1``-tick frozen-trunk pipeline inside every owner-iteration of the
scan, the executor runs ``pipeline.ring_phase_a_packed``'s single
``S*M + F - 1``-tick conveyor ONCE per round before the scan and feeds the
owner iterations from the resulting ``[S, M, ...]`` boundary stack — the
frozen trunk is round-constant, so the streams pack back-to-back and the
round saves ``(S-1)*(F-1)`` fill/drain ticks.  ``packed=False`` keeps the
per-owner scheme (A/B benchmarked in ``benchmarks/pipeline_bench.py``).

Frozen-trunk activation cache (Phase-A skip, ``core/actcache.py``): with a
``cache_capacity`` and slot-keyed batches, the executor builds up to three
executables per boundary —

  * ``direct``  — the PR-1 fused round (tokens in, no capture),
  * ``capture`` — same round, but each owner-iteration's stage-``F`` boundary
    activations are additionally emitted and written into the cache's donated
    device ring buffer (first visit of a ``(slot, boundary)`` key),
  * ``cached``  — takes ``(cache_buffer, row)`` instead of tokens and launches
    straight into Phase B: no embed, no ``all_gather``, no frozen-trunk ticks.
    The row and the owner are traced, so one executable serves every slot and
    owner; the gather of the cached activations happens on device.

``cache_dtype`` ({'native', 'f32', 'bf16', 'int8'}) compresses the cache's
entries — bf16 halves, int8 (per-row scales in a sidecar buffer) quarters the
bytes per entry, 2-4x more slots per byte of cache budget; the cached
executable dequantizes on device right after the row gather.

Boundary drops invalidate the whole cache (the unfreeze schedule is monotone
top-down — enforced here and in ``core/unfreeze.py``).  Batches whose shapes
don't fit the allocated buffer, or rounds without a slot key (streaming data),
fall back to ``direct``.

Heterogeneous rings (``spans=``): the executor runs any contiguous span
layout — ``partition.assign_layers`` output for speed-weighted heterogeneous
meshes (the paper's 4:5:2:3), or the balanced default.  The unfreeze boundary
aligns DOWN to span edges, the cache binds to the layout
(``ActivationCache.set_layout`` flushes it on ``repartition``), and
``measured_tick_ledger`` exposes the scan lengths actually traced per
``(boundary, mode)`` executable for the simulator-vs-executor differential
tests (tests/test_partition_exec.py).

Multi-tenant personalization (``tenants=T > 1``): one frozen trunk, T adapter
sets per ring.  Adapters/moments gain an interior tenant axis
([S, T, max_span, ...]; head [T, ...]), the packed conveyor chains all T·S·M
tenant-owner microbatches of a round into one ``T·S·M + F - 1``-tick Phase-A
pass (the trunk is frozen and bit-identical across tenants, and per-tick
shapes stay exactly single-tenant, so each microbatch's op sequence is
bit-identical to a solo run), Phase B + AdamW scan over the tenant axis with
single-tenant shapes inside, and the activation cache
partitions per tenant under ``(tenant, slot, boundary)`` keys with per-tenant
invalidation (``import_adapters`` flushes one tenant without touching its
neighbors).  Per tenant, a joint T-tenant session matches T independent
single-tenant sessions — asserted by tests/test_tenants.py.

Numerics match ``RingTrainer`` exactly (same ``adamw.leaf_update`` math,
constant lr, no bias correction) — asserted by tests/test_executor.py; the
cached path matches the uncached fused path — asserted by
tests/test_actcache.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import actcache
from repro.core import pipeline as pl
from repro.core.actcache import ActivationCache
from repro.core.partition import (DeviceProfile, Span, align_boundary,
                                  frozen_stage_count, spans_from_profiles)
from repro.core.unfreeze import UnfreezeSchedule, depth_to_boundary
from repro.optim import adamw

Array = jax.Array

FUSED_MODES = ("direct", "capture", "cached")


def scalarize(v: Any) -> Any:
    """Device metric value -> host scalar / list (non-arrays pass through).

    The ONE materialization rule for async metrics — shared by
    ``RingExecutor.materialize_metrics`` and ``repro.api.metrics``.
    """
    if isinstance(v, jax.Array):
        return float(v) if v.ndim == 0 else [float(x) for x in v]
    return v


def ring_opt_init(stage_blocks: Dict[str, Any], shared: Dict[str, Any]
                  ) -> Dict[str, Any]:
    """Ring optimizer state: adapter moments stage-stacked [S, lps, ...]
    (sharded with the adapters — optimizer state never crosses the ring, like
    the paper), head moments replicated."""
    m_ad, v_ad = adamw.init_moments(stage_blocks["adapter"])
    m_hd, v_hd = adamw.init_moments(shared["head"])
    return {"m": {"adapter": m_ad, "head": m_hd},
            "v": {"adapter": v_ad, "head": v_hd},
            "count": jnp.zeros((), jnp.int32)}


def ring_opt_specs() -> Dict[str, Any]:
    """PartitionSpec tree matching ``ring_opt_init``'s structure."""
    return {"m": {"adapter": P("stage"), "head": P()},
            "v": {"adapter": P("stage"), "head": P()},
            "count": P()}


def make_fused_round(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh, *,
                     n_stages: int, boundary: int, n_micro: int,
                     on_trace=None, mode: str = "direct",
                     packed: bool = True, cache_dtype: str = "native",
                     cache_src_dtype: Any = None,
                     spans: Optional[Sequence[Span]] = None,
                     tick_record=None, tenants: int = 1):
    """Build the fused round in one of three modes:

      direct :  fn(stage_blocks, shared, opt_state, tokens, labels)
                  -> (stage_blocks, shared, opt_state, (losses[S], mean))
      capture:  same signature, plus a trailing ``h_cap`` output
                ([S_stage, S_owner, M, mb, seq, D], sharded on 'stage'):
                every owner-iteration's Phase-A output, ready for the cache.
      cached :  fn(stage_blocks, shared, opt_state, cache_buf, row, labels)
                  -> (stage_blocks, shared, opt_state, (losses[S], mean))
                where ``cache_buf`` is the actcache ring buffer
                ([capacity, S_stage, S_owner, M, mb, seq, D], sharded
                P(None, 'stage')) and ``row`` a traced i32 row index.
                With ``cache_dtype='int8'`` the signature gains a
                ``cache_scales`` sidecar after ``cache_buf``; entries are
                dequantized on device right after the row gather
                (``actcache.dequantize`` with the static ``cache_dtype``).
                Phase A (embed + all_gather + frozen-trunk ticks) is absent
                from the executable entirely.

    ``packed`` (direct/capture only) selects the Phase-A scheme: True runs
    ``pipeline.ring_phase_a_packed``'s single ``S*M + F - 1``-tick conveyor
    once per round before the owner scan (the frozen trunk is round-constant,
    so all S owners' streams pack back-to-back, saving ``(S-1)*(F-1)``
    fill/drain ticks); False keeps the per-owner ``M + F - 1``-tick pipeline
    inside the scan (the PR-2 scheme, kept for A/B benchmarking).  Both are
    numerically the same per microbatch.  At ``F <= 1`` the saving is zero
    while the conveyor would still hold the whole ``[S*M, ...]`` stream live,
    so ``packed`` silently falls back to the scan there (measured ~9%
    slower otherwise on the 2-device mesh — see BENCH_ring_2dev.json).

    ``spans`` selects the stage layout ([(begin, end)] per stage, e.g. the
    paper's 4:5:2:3 from ``partition.assign_layers``); None is the balanced
    split.  ``boundary`` must be span-aligned.  ``tick_record(phase, ticks)``
    (if given) is called at trace time with each tick scan's length — the
    measured ledger tests/test_partition_exec.py pins against
    ``pipeline.pipeline_tick_counts``.

    Static per build: (boundary, mode, packed, cache_dtype, spans, tenants).
    ``on_trace`` (if given) is called each time the function body is traced
    — i.e. once per XLA compilation — which is how tests count executables.
    Wrap the result in ``jax.jit(..., donate_argnums=(0, 1, 2))``
    (RingExecutor does; the cache buffers are never donated — they outlive
    the round).

    Multi-tenant (``tenants=T > 1``): one frozen trunk, T adapter sets.
    Input trees gain one interior tenant axis — adapter leaves
    ``[S, T, max_span, ...]`` (still sharded P('stage')), head/opt-head
    ``[T, ...]`` (replicated), tokens/labels ``[S, T, M, mb, seq]`` — so
    every PartitionSpec is IDENTICAL to T=1.  Phase A runs once on the
    shared trunk with all tenants chained onto the conveyor's time axis
    (``ring_phase_a_packed(n_tenants=T)``); Phase B runs per tenant via a
    ``lax.scan`` over the stacked adapters (single-tenant shapes inside),
    and the masked AdamW update is elementwise on the stacked moments —
    both bit-equivalent to T independent single-tenant updates (the
    scalar stage mask broadcasts).  The metrics tuple gains a trailing
    ``tenant_losses [T]``; capture emits ``[T, S_stage, S_owner, M, ...]``
    (one cache entry per tenant) and cached mode takes a ``rows [T]``
    vector instead of a scalar row.
    """
    assert mode in FUSED_MODES, mode
    assert tenants >= 1, tenants
    T = tenants
    S = n_stages
    spans = pl.resolve_spans(cfg.repeats, S, spans)
    F = frozen_stage_count(spans, boundary)
    rec = tick_record or (lambda phase, t: None)
    phase_a = pl.ring_phase_a(cfg, n_stages=S, boundary=boundary,
                              n_micro=n_micro, spans=spans,
                              record=lambda t: rec("phase_a", t))
    phase_a_packed = pl.ring_phase_a_packed(
        cfg, n_stages=S, boundary=boundary, n_micro=n_micro, spans=spans,
        record=lambda t: rec("phase_a_packed", t), n_tenants=T)
    phase_b = pl.ring_phase_b(cfg, n_stages=S, boundary=boundary,
                              n_micro=n_micro, spans=spans,
                              record=lambda t: rec("phase_b", t))
    lr = jnp.float32(tc.learning_rate)
    # what Phase B received at capture time: compressed entries dequantize
    # back to exactly this dtype (the captured activations' own dtype when
    # the executor knows it, else the model compute dtype).
    compute_dtype = jnp.dtype(cache_src_dtype if cache_src_dtype is not None
                              else cfg.dtype)

    def run_round(stage_blocks, shared, opt_state, get_h_B, my_labels):
        """Owner scan + stage-masked optimizer, Phase-A source abstracted:
        ``get_h_B(owner, adapters)`` -> the stage-F injects [M, mb, seq, D]."""
        hot = (lax.axis_index("stage") >= F).astype(jnp.float32)
        my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)
        backbone = {k: v for k, v in my_blocks.items() if k != "adapter"}
        shared_rest = {k: v for k, v in shared.items() if k != "head"}
        unstage = lambda t: jax.tree.map(lambda x: x[0], t)
        restage = lambda t: jax.tree.map(lambda x: x[None], t)

        def owner_iter(carry, owner):
            ad, head, m_ad, v_ad, m_hd, v_hd = carry
            h_B = get_h_B(owner, ad)

            def local_loss(ad_, head_):
                return phase_b(owner, {**backbone, "adapter": ad_},
                               {**shared_rest, "head": head_}, h_B, my_labels)

            l_loc, (g_ad, g_hd) = jax.value_and_grad(
                local_loss, argnums=(0, 1))(ad, head)
            # head grads live only on the owner stage; psum replicates them
            # (same semantics as differentiating a replicated P() input).
            g_hd = jax.tree.map(lambda g: lax.psum(g, "stage"), g_hd)
            ad2, m_ad2, v_ad2 = adamw.tree_update(
                g_ad, m_ad, v_ad, ad, tc, lr=lr, mask=hot)
            head2, m_hd2, v_hd2 = adamw.tree_update(
                g_hd, m_hd, v_hd, head, tc, lr=lr)
            return (ad2, head2, m_ad2, v_ad2, m_hd2, v_hd2), (l_loc, h_B)

        init = (my_blocks["adapter"], shared["head"],
                unstage(opt_state["m"]["adapter"]), unstage(opt_state["v"]["adapter"]),
                opt_state["m"]["head"], opt_state["v"]["head"])
        (ad, head, m_ad, v_ad, m_hd, v_hd), (local_losses, h_caps) = lax.scan(
            owner_iter, init, jnp.arange(S))
        # each iteration's loss lives only on its owner stage; one vector psum
        # per round replicates all S of them at once.
        losses = lax.psum(local_losses, "stage")
        mean_loss = jnp.mean(losses)

        new_blocks = {**stage_blocks, "adapter": restage(ad)}
        new_shared = {**shared, "head": head}
        new_opt = {"m": {"adapter": restage(m_ad), "head": m_hd},
                   "v": {"adapter": restage(v_ad), "head": v_hd},
                   "count": opt_state["count"] + S}
        return new_blocks, new_shared, new_opt, (losses, mean_loss), h_caps

    def run_round_mt(stage_blocks, shared, opt_state, get_h_B, my_labels):
        """Multi-tenant owner scan: Phase B scans over the tenant axis, the
        masked AdamW update runs elementwise on the tenant-stacked moments.
        ``get_h_B(owner, adapters)`` -> [T, M, mb, seq, D]; ``my_labels``
        [T, M, mb, seq]; adapter leaves carry [T, max_span, ...] inside the
        scan, head leaves [T, ...].  Per tenant this is exactly
        ``run_round``'s math on exactly single-tenant shapes, so joint
        training equals T independent sessions bit-for-bit."""
        hot = (lax.axis_index("stage") >= F).astype(jnp.float32)
        my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)
        backbone = {k: v for k, v in my_blocks.items() if k != "adapter"}
        shared_rest = {k: v for k, v in shared.items() if k != "head"}
        unstage = lambda t: jax.tree.map(lambda x: x[0], t)
        restage = lambda t: jax.tree.map(lambda x: x[None], t)

        def owner_iter(carry, owner):
            ad, head, m_ad, v_ad, m_hd, v_hd = carry
            h_B = get_h_B(owner, ad)                 # [T, M, mb, seq, D]

            # Per-tenant Phase B over the stacked adapters: a lax.scan over
            # the tenant axis, NOT a vmap — inside the scan every tensor has
            # exactly the single-tenant shapes, so each tenant's grads (and
            # thus its Adam trajectory) are bit-identical to an independent
            # single-tenant session.  A vmap batches the kernels ([T, ...]
            # shapes), which reassociates reductions at the ulp level — and
            # the first Adam steps amplify ulp-level grad noise to O(lr)
            # sign flips, blowing the 1e-5/1e-3 differential pins.
            def per_tenant(_, args):
                ad_t, head_t, h_t, lab_t = args

                def local_loss(ad_, head_):
                    return phase_b(owner, {**backbone, "adapter": ad_},
                                   {**shared_rest, "head": head_}, h_t, lab_t)

                return None, jax.value_and_grad(
                    local_loss, argnums=(0, 1))(ad_t, head_t)

            _, (l_loc, (g_ad, g_hd)) = lax.scan(
                per_tenant, None, (ad, head, h_B, my_labels))  # l_loc [T]
            g_hd = jax.tree.map(lambda g: lax.psum(g, "stage"), g_hd)
            # stacked trees, same elementwise update: the scalar ``hot`` mask
            # broadcasts over the leading tenant axis.
            ad2, m_ad2, v_ad2 = adamw.tree_update(
                g_ad, m_ad, v_ad, ad, tc, lr=lr, mask=hot)
            head2, m_hd2, v_hd2 = adamw.tree_update(
                g_hd, m_hd, v_hd, head, tc, lr=lr)
            return (ad2, head2, m_ad2, v_ad2, m_hd2, v_hd2), (l_loc, h_B)

        init = (my_blocks["adapter"], shared["head"],
                unstage(opt_state["m"]["adapter"]), unstage(opt_state["v"]["adapter"]),
                opt_state["m"]["head"], opt_state["v"]["head"])
        (ad, head, m_ad, v_ad, m_hd, v_hd), (local_losses, h_caps) = lax.scan(
            owner_iter, init, jnp.arange(S))
        losses_to = lax.psum(local_losses, "stage")  # [S_owner, T]
        mean_loss = jnp.mean(losses_to)
        tenant_losses = losses_to.mean(axis=0)       # [T]
        losses = losses_to.mean(axis=1)              # [S] per-owner, T=1 shape

        new_blocks = {**stage_blocks, "adapter": restage(ad)}
        new_shared = {**shared, "head": head}
        new_opt = {"m": {"adapter": restage(m_ad), "head": m_hd},
                   "v": {"adapter": restage(v_ad), "head": v_hd},
                   "count": opt_state["count"] + S}
        return (new_blocks, new_shared, new_opt,
                (losses, mean_loss, tenant_losses), h_caps)

    run = run_round if T == 1 else run_round_mt
    met_spec = (P(), P()) if T == 1 else (P(), P(), P())

    if mode in ("direct", "capture"):

        def fused(stage_blocks, shared, opt_state, tokens, labels):
            if on_trace is not None:
                on_trace()
            my_tokens, my_labels = tokens[0], labels[0]
            my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)
            backbone = {k: v for k, v in my_blocks.items() if k != "adapter"}
            shared_rest = {k: v for k, v in shared.items() if k != "head"}

            # Embeddings are round-constant (outside the trainable set): embed +
            # gather once, not once per owner-iteration.
            seq = my_tokens.shape[-1]
            mb = my_tokens.shape[-2]
            pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                                   (mb, seq))
            if T == 1:
                emb_g = pl.gather_embeddings(cfg, shared_rest, my_tokens, pos)
            else:
                # my_tokens [T, M, mb, seq]: embed all tenants' microbatches
                # in one vmap, then restore the tenant axis.
                tok_flat = my_tokens.reshape((T * n_micro,)
                                             + my_tokens.shape[2:])
                e = pl.gather_embeddings(cfg, shared_rest, tok_flat, pos)
                emb_g = e.reshape((S, T, n_micro) + e.shape[2:])

            # The shared frozen trunk: Phase A reads only frozen adapter
            # rows, which are bit-identical across tenants (shared init +
            # stage mask), so any tenant's slice works — use tenant 0.
            trunk_ad = (my_blocks["adapter"] if T == 1 else
                        jax.tree.map(lambda x: x[0], my_blocks["adapter"]))

            if packed and F >= 2:
                # One continuous conveyor over ALL owners' frozen-trunk
                # streams, run before the scan.  Phase A only reads the
                # frozen stages' blocks, and the stage-masked optimizer keeps
                # those bit-identical across owner-iterations, so the
                # round-start adapters give exactly what each iteration's
                # carried adapters would have.  [S, M, ...] / [S, T, M, ...].
                h_B_all = phase_a_packed(
                    {**backbone, "adapter": trunk_ad}, emb_g)

                def get_h_B(owner, ad):
                    return lax.dynamic_index_in_dim(h_B_all, owner, 0,
                                                    keepdims=False)
            elif T == 1:

                def get_h_B(owner, ad):
                    return phase_a(owner, {**backbone, "adapter": ad}, emb_g)
            else:

                def get_h_B(owner, ad):
                    # Per-tenant Phase A as a lax.scan (NOT a vmap): inside
                    # the scan every tensor has exact single-tenant shapes,
                    # keeping each tenant's forward bit-identical to an
                    # independent session (see run_round_mt's Phase-B note).
                    trunk = {**backbone,
                             "adapter": jax.tree.map(lambda x: x[0], ad)}

                    def per_tenant(_, e_t):
                        return None, phase_a(owner, trunk, e_t)

                    _, h = lax.scan(per_tenant, None,
                                    jnp.swapaxes(emb_g, 0, 1))
                    return h                             # [T, M, mb, seq, D]

            blocks2, shared2, opt2, metrics, h_caps = run(
                stage_blocks, shared, opt_state, get_h_B, my_labels)
            if mode == "capture":
                # packed capture writes the whole owner stack in one pass —
                # h_caps is the scan-stacked copy of h_B_all either way.
                if T == 1:
                    return blocks2, shared2, opt2, metrics, h_caps[None]
                # [S_owner, T, M, ...] -> [T, S_stage=1, S_owner, M, ...]:
                # one buffer entry per tenant, each the T=1 entry shape.
                return (blocks2, shared2, opt2, metrics,
                        jnp.swapaxes(h_caps, 0, 1)[:, None])
            return blocks2, shared2, opt2, metrics

        opt_spec = ring_opt_specs()
        out = (P("stage"), P(), opt_spec, met_spec)
        if mode == "capture":
            out = out + ((P("stage"),) if T == 1 else (P(None, "stage"),))
        return compat.shard_map(
            fused, mesh=mesh,
            in_specs=(P("stage"), P(), opt_spec, P("stage"), P("stage")),
            out_specs=out)

    # mode == "cached": Phase A replaced by an on-device gather from the ring
    # buffer — the executable never sees tokens or the embedding table.
    # Compressed entries are dequantized right after the row gather, inside
    # this executable (static ``cache_dtype``), then fed to Phase B in the
    # model's compute dtype — a hit costs zero host<->device traffic at any
    # storage precision.
    def cached_body(stage_blocks, shared, opt_state, h_slot, labels):
        my_labels = labels[0]

        # T=1: h_slot [S_owner, M, ...]; T>1: [T, S_owner, M, ...] — the
        # owner index sits after the tenant axis.
        def get_h_B(owner, ad):
            return lax.dynamic_index_in_dim(h_slot, owner, 0 if T == 1 else 1,
                                            keepdims=False)

        blocks2, shared2, opt2, metrics, _ = run(
            stage_blocks, shared, opt_state, get_h_B, my_labels)
        return blocks2, shared2, opt2, metrics

    def _row(buf, row):
        # [cap, S_stage=1(local), S_owner, ...] -> this stage's row(s).
        # T=1: scalar row -> [S_owner, ...]; T>1: rows [T] -> a gather
        # [T, S_owner, ...] (one buffer row per tenant).
        if T == 1:
            return lax.dynamic_index_in_dim(buf[:, 0], row, 0, keepdims=False)
        return buf[:, 0][row]

    if cache_dtype == "int8":

        def fused_cached_q(stage_blocks, shared, opt_state, cache_buf,
                           cache_scales, row, labels):
            if on_trace is not None:
                on_trace()
            h_slot = actcache.dequantize(
                _row(cache_buf, row), _row(cache_scales, row), "int8",
                compute_dtype)
            return cached_body(stage_blocks, shared, opt_state, h_slot,
                               labels)

        opt_spec = ring_opt_specs()
        return compat.shard_map(
            fused_cached_q, mesh=mesh,
            in_specs=(P("stage"), P(), opt_spec, P(None, "stage"),
                      P(None, "stage"), P(), P("stage")),
            out_specs=(P("stage"), P(), opt_spec, met_spec))

    def fused_cached(stage_blocks, shared, opt_state, cache_buf, row, labels):
        if on_trace is not None:
            on_trace()
        h_slot = actcache.dequantize(_row(cache_buf, row), None, cache_dtype,
                                     compute_dtype)
        return cached_body(stage_blocks, shared, opt_state, h_slot, labels)

    opt_spec = ring_opt_specs()
    return compat.shard_map(
        fused_cached, mesh=mesh,
        in_specs=(P("stage"), P(), opt_spec, P(None, "stage"), P(),
                  P("stage")),
        out_specs=(P("stage"), P(), opt_spec, met_spec))


class RingExecutor:
    """Collaborative fine-tuning over a ring of ``n_stages`` devices — fused.

    Drop-in upgrade of ``core/ring.py``'s ``RingTrainer``: same constructor,
    same ``round(tokens, labels)`` / ``export_params()`` surface, but each
    round is ONE donated executable instead of S dispatches + a host-side
    optimizer loop, and ``round()`` never blocks on the host (metrics are
    device arrays; see ``materialize_metrics``).

    With ``cache_capacity > 0``, pass ``slot=<stable batch-slot id>`` to
    ``round``: steady-state revisits of a ``(slot, boundary)`` key skip
    Phase A entirely (see module docstring).  ``slot=None`` (or capacity 0)
    preserves the PR-1 behavior exactly.

    The unfreeze boundary is evaluated once per round (at the round's first
    step).  When ``tc.unfreeze_interval`` is a multiple of ``n_stages`` this is
    identical to the reference trainer's per-iteration evaluation; otherwise a
    mid-round bump is deferred to the next round boundary.
    """

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                 params: Dict[str, Any], n_stages: int, n_micro: int, *,
                 donate: bool = True, cache_capacity: int = 0,
                 schedule: Optional[Any] = None, packed: bool = True,
                 cache_dtype: str = "native",
                 spans: Optional[Sequence[Span]] = None,
                 tenants: int = 1):
        assert len(cfg.pattern) == 1, "ring executor needs a uniform pattern"
        assert tenants >= 1, tenants
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.S, self.M = n_stages, n_micro
        self.T = tenants
        self.packed = packed
        self.cache_dtype = cache_dtype
        # ``spans`` makes heterogeneous (uneven, assign_layers-produced)
        # stage layouts first-class; None is the balanced split — identical
        # to the historical L/S-per-stage layout when R divides evenly.
        self.spans = pl.resolve_spans(cfg.repeats, n_stages, spans)
        # lps only exists for uniform layouts (back-compat for benches/tests
        # that reason in blocks-per-stage); ragged layouts use self.spans.
        self.lps = (cfg.repeats // n_stages
                    if not pl.is_ragged(self.spans) else None)
        self.stage_blocks, self.shared = pl.stage_stack(params, cfg, n_stages,
                                                        spans=self.spans)
        if tenants > 1:
            # One frozen trunk, T adapter sets: adapters gain an interior
            # tenant axis [S, T, max_span, ...] (stage axis stays leading so
            # the P('stage') specs are unchanged); the head is per-tenant
            # [T, ...].  All tenants start from the same init — the shared
            # Phase-A trunk relies on frozen rows staying bit-identical.
            self.stage_blocks = {
                **self.stage_blocks,
                "adapter": adamw.tenant_stack(
                    self.stage_blocks["adapter"], tenants, axis=1)}
            self.shared = {
                **self.shared,
                "head": adamw.tenant_stack(self.shared["head"], tenants)}
        self._params_rest = {k: v for k, v in params.items()
                             if k not in ("blocks",)}
        self.opt_state = ring_opt_init(self.stage_blocks, self.shared)
        # per-tenant cache accounting (satellite of the partitioned cache:
        # a tenant's invalidation must not move its neighbors' hit-rates)
        self.tenant_hits = [0] * tenants
        self.tenant_misses = [0] * tenants
        # Any object with ``depth_at(step, n_blocks) -> int`` works here
        # (repro.api's UnfreezePolicy protocol); the monotone-boundary
        # contract is still re-checked at runtime in ``round`` regardless of
        # who supplies the depths.
        self.sched = (schedule if schedule is not None
                      else UnfreezeSchedule.from_train_config(tc))
        self.donate = donate
        self.cache: Optional[ActivationCache] = None
        if cache_capacity:
            self.cache = ActivationCache(
                cache_capacity, dtype=cache_dtype,
                sharding=NamedSharding(mesh, P(None, "stage")),
                layout=self.spans)
        self._fns: Dict[Tuple[int, str], Any] = {}  # (boundary, mode) -> jit fn
        self.trace_counts: Dict[int, int] = {}      # boundary -> #compilations
        self.mode_trace_counts: Dict[Tuple[int, str], int] = {}
        # (boundary, mode) -> {phase: scan length} — the scan lengths XLA
        # actually traced (pipeline._tick_phase reports them); the measured
        # side of the simulator-vs-executor differential harness.
        self.tick_scan_lens: Dict[Tuple[int, str], Dict[str, int]] = {}
        self._last_boundary: Optional[int] = None
        self.step = 0

    # ------------------------------------------------------------------
    def boundary_at(self, step: int) -> int:
        depth = self.sched.depth_at(step, self.cfg.n_layers)
        b = depth_to_boundary(self.cfg, depth)
        return align_boundary(self.spans, b)       # span-aligned (terminator)

    def _fn(self, boundary: int, mode: str = "direct"):
        key = (boundary, mode)
        if key not in self._fns:
            self.trace_counts.setdefault(boundary, 0)

            def bump(b=boundary, mo=mode):
                self.trace_counts[b] += 1
                self.mode_trace_counts[(b, mo)] = (
                    self.mode_trace_counts.get((b, mo), 0) + 1)

            def tick_rec(phase, t, k=key):
                self.tick_scan_lens.setdefault(k, {})[phase] = t

            src_dt = (self.cache.src_dtype if self.cache is not None
                      else None)
            fused = make_fused_round(self.cfg, self.tc, self.mesh,
                                     n_stages=self.S, boundary=boundary,
                                     n_micro=self.M, on_trace=bump, mode=mode,
                                     packed=self.packed,
                                     cache_dtype=self.cache_dtype,
                                     cache_src_dtype=src_dt,
                                     spans=self.spans, tick_record=tick_rec,
                                     tenants=self.T)
            donate = (0, 1, 2) if self.donate else ()
            self._fns[key] = jax.jit(fused, donate_argnums=donate)
        return self._fns[key]

    def measured_tick_ledger(self, boundary: int, mode: str = "direct"
                             ) -> Dict[str, int]:
        """Per-round tick totals from the scan lengths actually traced into
        the (boundary, mode) executable — the measured half of the
        simulator-vs-executor differential harness.  Matches the key schema
        of ``pipeline.pipeline_tick_counts`` so tests can compare directly.

        The executable must have been built (one round run, or ``_fn``
        called) — raises KeyError otherwise.
        """
        if (boundary, mode) not in self._fns:
            raise KeyError(
                f"no ({boundary}, {mode!r}) executable built yet — run a "
                f"round at that boundary first")
        rec = self.tick_scan_lens.get((boundary, mode), {})
        S, M = self.S, self.M
        F = frozen_stage_count(self.spans, boundary)
        tb = rec.get("phase_b")
        assert tb is not None, (boundary, mode, rec)
        if "phase_a_packed" in rec:
            a_round = rec["phase_a_packed"]          # one conveyor per round
            a_per_owner = 0                          # hoisted out of the scan
        elif "phase_a" in rec:
            a_round = S * rec["phase_a"]             # traced once, scanned S x
            a_per_owner = rec["phase_a"]
        else:                                        # cached mode or F == 0
            a_round = 0
            a_per_owner = 0
        saved = (S * (M + F - 1) - a_round
                 if "phase_a_packed" in rec and F > 0 else 0)
        return {
            "fwd_ticks": a_per_owner + tb,
            "bwd_ticks": tb,                         # grad reverses the scan
            "frozen_stages": F,
            "hot_stages": S - F,
            "phase_a_round_ticks": a_round,
            "phase_a_saved_ticks": saved,
        }

    @property
    def n_executables(self) -> int:
        return len(self._fns)

    def compile_counts(self) -> Dict[str, int]:
        """{'<boundary>/<mode>': traces} — the bench's per-boundary record."""
        return {f"{b}/{mode}": n
                for (b, mode), n in sorted(self.mode_trace_counts.items())}

    # ------------------------------------------------------------------
    def _entry_shape(self, labels: Array):
        """Global shape of one cache entry for the current batch
        ([S_stage, S_owner, M, mb, seq, D]; dtype is whatever capture stored).
        Multi-tenant entries keep the SAME per-entry shape — each tenant owns
        its own buffer row under its own ``(tenant, slot, boundary)`` key."""
        if self.T > 1:
            _, _, M, mb, seq = labels.shape
        else:
            _, M, mb, seq = labels.shape
        return (self.S, self.S, M, mb, seq, self.cfg.d_model)

    def _keys(self, slot: int, boundary: int):
        """Cache keys for this round: ``(slot, boundary)`` at T=1 (the PR-4
        schema, unchanged); ``(tenant, slot, boundary)`` per tenant at T>1."""
        if self.T == 1:
            return [(slot, boundary)]
        return [(t, slot, boundary) for t in range(self.T)]

    def round(self, tokens: Array, labels: Array, *,
              slot: Optional[int] = None) -> Dict[str, Any]:
        """One training round: every client acts as initiator once.

        tokens/labels: [S, M, mb, seq] per-client local data for this round
        ([S, T, M, mb, seq] when ``tenants > 1`` — axis 1 is the tenant).
        slot: stable batch-slot id (same slot => same examples, the cache-key
        contract; see ``data.pipeline.RingBatcher`` with ``slots_per_epoch``).
        Returns metrics as DEVICE arrays — no host sync.  Use
        ``materialize_metrics`` (or ``float()``) at your logging interval.
        Multi-tenant rounds add ``tenant_losses`` ([T] device array) and hit
        only when EVERY tenant's key is resident (a partial-hit round re-runs
        the shared conveyor once and refreshes all T entries; the per-tenant
        ``index_of`` calls keep per-tenant hit accounting honest).
        """
        boundary = self.boundary_at(self.step)
        if self._last_boundary is not None and boundary > self._last_boundary:
            raise RuntimeError(
                f"unfreeze boundary increased {self._last_boundary} -> "
                f"{boundary} at step {self.step}; RingAda schedules are "
                f"monotone top-down and the activation cache's invalidation "
                f"contract depends on it (see core/unfreeze.py)")
        if (self.cache is not None and self._last_boundary is not None
                and boundary < self._last_boundary):
            self.cache.invalidate()                # boundary drop: all keys dead
        self._last_boundary = boundary

        cache_hit = False
        tenant_losses = None
        use_cache = self.cache is not None and slot is not None
        if use_cache:
            if not self.cache.compatible(self._entry_shape(labels)):
                self.cache.bypasses += 1           # batch doesn't fit the buffer
                use_cache = False

        if use_cache:
            keys = self._keys(slot, boundary)
            rows = [self.cache.index_of(k) for k in keys]
            if self.T > 1:
                for t, r in enumerate(rows):
                    if r is None:
                        self.tenant_misses[t] += 1
                    else:
                        self.tenant_hits[t] += 1
            if all(r is not None for r in rows):
                fn = self._fn(boundary, "cached")
                row_arg = (jnp.int32(rows[0]) if self.T == 1
                           else jnp.asarray(rows, jnp.int32))
                if self.cache_dtype == "int8":
                    (self.stage_blocks, self.shared, self.opt_state,
                     mets) = fn(
                        self.stage_blocks, self.shared, self.opt_state,
                        self.cache.buffer, self.cache.scales,
                        row_arg, labels)
                else:
                    (self.stage_blocks, self.shared, self.opt_state,
                     mets) = fn(
                        self.stage_blocks, self.shared, self.opt_state,
                        self.cache.buffer, row_arg, labels)
                cache_hit = True
            else:
                fn = self._fn(boundary, "capture")
                (self.stage_blocks, self.shared, self.opt_state,
                 mets, h_cap) = fn(
                    self.stage_blocks, self.shared, self.opt_state,
                    tokens, labels)
                if self.T == 1:
                    self.cache.put(keys[0], h_cap)
                else:
                    # h_cap [T, S_stage, S_owner, M, mb, seq, D]: one entry
                    # per tenant, each the T=1 entry shape — a tenant that
                    # already hit gets its (identical) bits refreshed in place.
                    for t, k in enumerate(keys):
                        self.cache.put(k, h_cap[t])
        else:
            fn = self._fn(boundary, "direct")
            (self.stage_blocks, self.shared, self.opt_state,
             mets) = fn(
                self.stage_blocks, self.shared, self.opt_state, tokens, labels)

        if self.T == 1:
            losses, mean_loss = mets
        else:
            losses, mean_loss, tenant_losses = mets

        self.step += self.S
        out = {"loss": mean_loss, "losses": losses,
               "boundary": boundary, "step": self.step,
               "cache_hit": cache_hit}
        if tenant_losses is not None:
            out["tenant_losses"] = tenant_losses
            out["tenant_cache_hits"] = list(self.tenant_hits)
            out["tenant_cache_misses"] = list(self.tenant_misses)
        if self.cache is not None:
            out.update(self.cache.stats())
        return out

    @staticmethod
    def materialize_metrics(m: Dict[str, Any]) -> Dict[str, Any]:
        """Host-sync a metrics dict (the once-per-logging-interval sync)."""
        return {k: scalarize(v) for k, v in m.items()}

    # ------------------------------------------------------------------
    def repartition(self, spans: Sequence[Span]) -> None:
        """Switch to a new span layout mid-run (the elastic-membership /
        re-profiling hook): restacks the live params AND Adam moments into
        the new padded layout, drops every built executable (the layout is
        static per build), flushes the activation cache (its entries' stage-F
        location is layout-dependent — ``ActivationCache.set_layout``), and
        re-seeds the monotone-boundary check (alignment granularity changed,
        so the span-aligned boundary may legitimately move up toward the raw
        schedule value).
        """
        new = pl.resolve_spans(self.cfg.repeats, self.S, spans)
        if new == self.spans:
            return
        old = self.spans
        if self.T == 1:
            params = self.export_params()            # flat [R, ...] canonical
            m_ad = pl.unstack_entry(self.opt_state["m"]["adapter"], old)
            v_ad = pl.unstack_entry(self.opt_state["v"]["adapter"], old)
            self.spans = new
            self.lps = (self.cfg.repeats // self.S
                        if not pl.is_ragged(new) else None)
            self.stage_blocks, self.shared = pl.stage_stack(
                params, self.cfg, self.S, spans=new)
            self._params_rest = {k: v for k, v in params.items()
                                 if k != "blocks"}
            self.opt_state = {
                **self.opt_state,
                "m": {**self.opt_state["m"],
                      "adapter": pl.stack_entry(m_ad, new)},
                "v": {**self.opt_state["v"],
                      "adapter": pl.stack_entry(v_ad, new)},
            }
        else:
            # Restack ALL tenants: backbone once, every tenant's adapters
            # and moments through the tenant-major [T, R, ...] flat form.
            bb_flat = self._unstack_backbone(old)
            ad_flat = self._unstack_adapters(self.stage_blocks["adapter"], old)
            m_flat = self._unstack_adapters(
                self.opt_state["m"]["adapter"], old)
            v_flat = self._unstack_adapters(
                self.opt_state["v"]["adapter"], old)
            self.spans = new
            self.lps = (self.cfg.repeats // self.S
                        if not pl.is_ragged(new) else None)
            self.stage_blocks = {
                **pl.stack_entry(bb_flat, new),
                "adapter": self._stack_adapters(ad_flat, new)}
            self.opt_state = {
                **self.opt_state,
                "m": {**self.opt_state["m"],
                      "adapter": self._stack_adapters(m_flat, new)},
                "v": {**self.opt_state["v"],
                      "adapter": self._stack_adapters(v_flat, new)},
            }
        self._fns.clear()
        if self.cache is not None:
            self.cache.set_layout(new)
        self._last_boundary = None

    # ------------------------------------------------------------------
    # elastic membership: live S -> S-1 shrink / S -> S+1 grow
    # ------------------------------------------------------------------

    def _resolve_new_spans(self, new_S: int,
                           spans: Optional[Sequence[Span]],
                           profiles: Optional[Sequence[DeviceProfile]]
                           ) -> Tuple[Span, ...]:
        R = self.cfg.repeats
        if R < new_S:
            raise ValueError(
                f"cannot run {new_S} stages over {R} blocks")
        if spans is not None:
            return pl.resolve_spans(R, new_S, spans)
        if profiles is not None:
            if len(profiles) != new_S:
                raise ValueError(
                    f"got {len(profiles)} profiles for a {new_S}-stage ring")
            return spans_from_profiles(R, list(profiles))
        return pl.resolve_spans(R, new_S, None)

    def _regeometry(self, new_S: int, new_spans: Tuple[Span, ...]) -> None:
        """Rebuild the executor at a new ring size in place.

        Everything the ring holds is round-trips through its canonical
        (unstacked, host) form: params via ``export_params`` /
        ``load_canonical``, Adam moments via the flat entry form — the same
        exact restack ``repartition`` does, plus a mesh change.  The host
        hop (``np.asarray``) detaches every leaf from the old mesh's
        sharding so the rebuilt stacks place cleanly on the new one.  The
        activation cache is REBOUND, not restored: entry shapes carry S, so
        the old buffer cannot be reused — the next round's capture
        executable refills it (checkpoint-free recovery).  Counters /
        trace histories survive; executables and tick ledgers do not (the
        geometry they were traced for is gone).
        """
        host = lambda t: jax.tree.map(np.asarray, t)
        old = self.spans
        params = host(self.export_params(None if self.T > 1 else 0))
        if self.T == 1:
            m_ad = host(pl.unstack_entry(self.opt_state["m"]["adapter"], old))
            v_ad = host(pl.unstack_entry(self.opt_state["v"]["adapter"], old))
        else:
            m_ad = host(self._unstack_adapters(
                self.opt_state["m"]["adapter"], old))
            v_ad = host(self._unstack_adapters(
                self.opt_state["v"]["adapter"], old))
        m_hd = host(self.opt_state["m"]["head"])
        v_hd = host(self.opt_state["v"]["head"])
        count = np.asarray(self.opt_state["count"])

        self.S = new_S
        self.mesh = compat.make_mesh((new_S,), ("stage",))
        self.spans = new_spans
        self.lps = (self.cfg.repeats // new_S
                    if not pl.is_ragged(new_spans) else None)
        self.load_canonical(params)
        stack = ((lambda t: pl.stack_entry(t, new_spans)) if self.T == 1
                 else (lambda t: self._stack_adapters(t, new_spans)))
        self.opt_state = {"m": {"adapter": stack(m_ad), "head": m_hd},
                          "v": {"adapter": stack(v_ad), "head": v_hd},
                          "count": jnp.asarray(count)}
        self._fns.clear()
        self.tick_scan_lens.clear()
        if self.cache is not None:
            self.cache.rebind(
                sharding=NamedSharding(self.mesh, P(None, "stage")),
                layout=new_spans)
        self._last_boundary = None

    def shrink(self, dead_stage: int, *,
               spans: Optional[Sequence[Span]] = None,
               profiles: Optional[Sequence[DeviceProfile]] = None) -> None:
        """Degraded S-1 operation after stage ``dead_stage`` dies.

        The dead device's span is reassigned over the survivors — via
        explicit ``spans``, via ``spans_from_profiles`` over the survivors'
        ``profiles``, or the balanced split.  Nothing is lost to the crash:
        adapters and Adam moments are stage-stacked but every stage's rows
        are recoverable from the canonical round-trip (the donated stacks
        replicate the flat entry form across the SPMD round), so live state
        restacks exactly, the unfreeze boundary aligns DOWN to the new span
        edges, and the activation cache re-captures on the next round —
        no checkpoint restore anywhere on the path.
        """
        if not 0 <= dead_stage < self.S:
            raise ValueError(
                f"dead_stage {dead_stage} out of range for S={self.S}")
        if self.S <= 1:
            raise RuntimeError("cannot shrink a 1-stage ring")
        self._regeometry(self.S - 1,
                         self._resolve_new_spans(self.S - 1, spans, profiles))

    def grow(self, profile: Optional[DeviceProfile] = None, *,
             spans: Optional[Sequence[Span]] = None,
             profiles: Optional[Sequence[DeviceProfile]] = None) -> None:
        """Inverse of ``shrink``: a device joins, S grows by one.

        ``profiles`` (or explicit ``spans``) describe the FULL post-join
        fleet; passing just ``profile`` appends a joining device to an
        otherwise-unprofiled ring (balanced split plus the newcomer's
        speed is meaningless, so that case uses ``spans_from_profiles``
        over unit-speed incumbents + the newcomer).
        """
        new_S = self.S + 1
        if jax.device_count() < new_S:
            raise RuntimeError(
                f"grow to S={new_S} needs {new_S} devices, have "
                f"{jax.device_count()}")
        if profiles is None and spans is None and profile is not None:
            profiles = [DeviceProfile(1.0, float("inf"))] * self.S + [profile]
        self._regeometry(new_S,
                         self._resolve_new_spans(new_S, spans, profiles))

    # ------------------------------------------------------------------
    # canonical <-> stacked forms (tenant-aware)
    # ------------------------------------------------------------------

    def _unstack_backbone(self, spans) -> Dict[str, Any]:
        """Non-adapter stage blocks -> flat [R, ...] leaves."""
        bb = {k: v for k, v in self.stage_blocks.items() if k != "adapter"}
        return pl.unstack_entry(bb, spans)

    def _unstack_adapters(self, stacked: Any, spans) -> Any:
        """[S, T, max_span, ...] leaves -> tenant-major flat [T, R, ...]."""
        t_major = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), stacked)
        return pl.unstack_entry(t_major, spans, leading=1)

    def _stack_adapters(self, flat_t: Any, spans) -> Any:
        """Inverse of ``_unstack_adapters``: [T, R, ...] -> [S, T, max_span, ...]."""
        t_major = pl.stack_entry(flat_t, spans, leading=1)
        return jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), t_major)

    # ------------------------------------------------------------------
    def export_params(self, tenant: Optional[int] = None) -> Dict[str, Any]:
        """Canonical (unstacked) param tree.

        T=1: the familiar single-model tree (``tenant`` must be None or 0).
        T>1 with ``tenant=t``: tenant t's complete single-model tree (shared
        trunk + its adapters + its head) — directly loadable by serving.
        T>1 with ``tenant=None``: the tenant-stacked checkpoint tree —
        adapter leaves [T, R, ...], head leaves [T, ...], trunk unstacked.
        """
        if self.T == 1:
            assert tenant in (None, 0), tenant
            return pl.unstack(self.stage_blocks, self.cfg, self._params_rest,
                              self.shared, spans=self.spans)
        bb_flat = self._unstack_backbone(self.spans)
        ad_flat = self._unstack_adapters(self.stage_blocks["adapter"],
                                         self.spans)
        if tenant is None:
            entry = {**bb_flat, "adapter": ad_flat}
            return {**self._params_rest, **self.shared, "blocks": (entry,)}
        entry = {**bb_flat,
                 "adapter": jax.tree.map(lambda x: x[tenant], ad_flat)}
        shared = {**self.shared,
                  "head": jax.tree.map(lambda x: x[tenant],
                                       self.shared["head"])}
        return {**self._params_rest, **shared, "blocks": (entry,)}

    # ------------------------------------------------------------------
    def export_adapters(self, tenant: int = 0) -> Dict[str, Any]:
        """One tenant's trainable set as a flat bundle:
        ``{"adapter": [R, ...] tree, "head": head tree}`` — the unit the
        AdapterStore persists and serving hot-swaps."""
        assert 0 <= tenant < self.T, (tenant, self.T)
        if self.T == 1:
            ad = pl.unstack_entry(self.stage_blocks["adapter"], self.spans)
            return {"adapter": ad, "head": self.shared["head"]}
        ad_flat = self._unstack_adapters(self.stage_blocks["adapter"],
                                         self.spans)
        return {"adapter": jax.tree.map(lambda x: x[tenant], ad_flat),
                "head": jax.tree.map(lambda x: x[tenant],
                                     self.shared["head"])}

    def import_adapters(self, tenant: int, bundle: Dict[str, Any]) -> None:
        """Install a flat adapter bundle into tenant ``tenant``'s slot and
        invalidate ONLY that tenant's cache partition (its stage-F inputs may
        now differ; neighbors' entries stay valid)."""
        assert 0 <= tenant < self.T, (tenant, self.T)
        ad_stacked = pl.stack_entry(bundle["adapter"], self.spans)
        if self.T == 1:
            self.stage_blocks = {**self.stage_blocks, "adapter": ad_stacked}
            self.shared = {**self.shared, "head": bundle["head"]}
            if self.cache is not None:
                self.cache.invalidate()
            return
        self.stage_blocks = {
            **self.stage_blocks,
            "adapter": jax.tree.map(
                lambda cur, new: cur.at[:, tenant].set(new),
                self.stage_blocks["adapter"], ad_stacked)}
        self.shared = {
            **self.shared,
            "head": jax.tree.map(lambda cur, new: cur.at[tenant].set(new),
                                 self.shared["head"], bundle["head"])}
        if self.cache is not None:
            self.cache.invalidate_tenant(tenant)

    def export_tenant_opt(self, tenant: int = 0) -> Dict[str, Any]:
        """One tenant's optimizer state in the flat bundle layout (moments
        shaped like ``export_adapters``; ``count`` is the shared step)."""
        assert 0 <= tenant < self.T, (tenant, self.T)

        def flat_moment(tree):
            if self.T == 1:
                return {"adapter": pl.unstack_entry(tree["adapter"],
                                                    self.spans),
                        "head": tree["head"]}
            ad = self._unstack_adapters(tree["adapter"], self.spans)
            return {"adapter": jax.tree.map(lambda x: x[tenant], ad),
                    "head": jax.tree.map(lambda x: x[tenant], tree["head"])}

        return {"m": flat_moment(self.opt_state["m"]),
                "v": flat_moment(self.opt_state["v"]),
                "count": self.opt_state["count"]}

    def import_tenant_opt(self, tenant: int, opt: Dict[str, Any]) -> None:
        """Install flat per-tenant moments (inverse of ``export_tenant_opt``;
        ``count`` is shared ring state and is left untouched at T>1)."""
        assert 0 <= tenant < self.T, (tenant, self.T)

        def set_moment(cur, flat):
            ad_stacked = pl.stack_entry(flat["adapter"], self.spans)
            if self.T == 1:
                return {"adapter": ad_stacked, "head": flat["head"]}
            return {"adapter": jax.tree.map(
                        lambda c, n: c.at[:, tenant].set(n),
                        cur["adapter"], ad_stacked),
                    "head": jax.tree.map(lambda c, n: c.at[tenant].set(n),
                                         cur["head"], flat["head"])}

        new = {"m": set_moment(self.opt_state["m"], opt["m"]),
               "v": set_moment(self.opt_state["v"], opt["v"]),
               "count": (opt["count"] if self.T == 1
                         else self.opt_state["count"])}
        self.opt_state = new

    def load_canonical(self, params: Dict[str, Any]) -> None:
        """Install a canonical tree from ``export_params()`` (T=1 single-model
        or T>1 tenant-stacked) back into the live stage layout."""
        if self.T == 1:
            self.stage_blocks, self.shared = pl.stage_stack(
                params, self.cfg, self.S, spans=self.spans)
            self._params_rest = {k: v for k, v in params.items()
                                 if k != "blocks"}
            return
        entry = params["blocks"][0]
        bb_flat = {k: v for k, v in entry.items() if k != "adapter"}
        self.stage_blocks = {
            **pl.stack_entry(bb_flat, self.spans),
            "adapter": self._stack_adapters(entry["adapter"], self.spans)}
        self.shared = {k: params[k] for k in self.shared}
        self._params_rest = {k: v for k, v in params.items()
                             if k != "blocks"}
