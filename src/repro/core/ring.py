"""RingTrainer: the *reference* (unfused) RingAda driver (Algorithm 1).

Executor split: this module keeps the paper's round-robin-initiator trainer in
its original, easy-to-audit form — one executable per (owner, boundary) pair,
optimizer on the host between dispatches — while ``core/executor.py``'s
``RingExecutor`` is the production path that fuses the whole round (S
owner-iterations + stage-masked AdamW) into one donated, jitted executable.
Both share the ring round construction in ``core/pipeline.py`` and the
optimizer math in ``optim/adamw.py`` (``leaf_update`` with no bias correction,
constant lr), so they are numerically interchangeable; tests/test_executor.py
pins that equivalence.  Keep this class as the oracle when touching either.

Semantics (both drivers):

  * the initiator rotates per round (paper: next initiator = best channel
    quality; under a homogeneous ICI ring this degenerates to round-robin,
    which is also what the paper's experiments use),
  * the coordinator-side unfreeze schedule bumps the depth every k steps,
  * adapter moments live stage-local (sharded with the adapters — optimizer
    state never crosses the ring, like the paper), head moments are replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pipeline as pl
from repro.core.partition import Span, align_boundary, frozen_stage_count
from repro.core.unfreeze import UnfreezeSchedule, depth_to_boundary
from repro.optim import adamw

Array = jax.Array


class RingTrainer:
    """Collaborative fine-tuning over a ring of ``n_stages`` devices.

    Reference implementation: S jit dispatches per round, host-side optimizer,
    one ``float(loss)`` sync per iteration.  Use ``core.executor.RingExecutor``
    for the fused single-dispatch round.
    """

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                 params: Dict[str, Any], n_stages: int, n_micro: int, *,
                 schedule=None, spans: Optional[Sequence[Span]] = None):
        assert len(cfg.pattern) == 1, "ring trainer needs a uniform pattern"
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.S, self.M = n_stages, n_micro
        self.spans = pl.resolve_spans(cfg.repeats, n_stages, spans)
        self.lps = (cfg.repeats // n_stages
                    if not pl.is_ragged(self.spans) else None)
        self.stage_blocks, self.shared = pl.stage_stack(params, cfg, n_stages,
                                                        spans=self.spans)
        self._params_rest = {k: v for k, v in params.items()
                             if k not in ("blocks",)}
        self.m_ad, self.v_ad = adamw.init_moments(self.stage_blocks["adapter"])
        self.m_hd, self.v_hd = adamw.init_moments(self.shared["head"])
        # ``schedule`` may be any object with depth_at(step, n_blocks) -> int
        # (e.g. a repro.api UnfreezePolicy); defaults to the paper's k-rule.
        self.sched = (schedule if schedule is not None
                      else UnfreezeSchedule.from_train_config(tc))
        self._round_fns: Dict[Tuple[int, int], Any] = {}
        self.step = 0

    # ------------------------------------------------------------------
    def _boundary_at(self, step: int) -> int:
        depth = self.sched.depth_at(step, self.cfg.n_layers)
        b = depth_to_boundary(self.cfg, depth)
        return align_boundary(self.spans, b)   # span-aligned (terminator device)

    def _fn(self, owner: int, boundary: int):
        key = (owner, boundary)
        if key not in self._round_fns:
            fn = pl.make_ring_train_round(
                self.cfg, self.mesh, n_stages=self.S, owner=owner,
                boundary=boundary, n_micro=self.M, spans=self.spans)
            self._round_fns[key] = jax.jit(fn)
        return self._round_fns[key]

    @property
    def n_executables(self) -> int:
        """One per (owner, boundary) pair — S x boundaries (the fused executor
        needs one per boundary)."""
        return len(self._round_fns)

    # ------------------------------------------------------------------
    def round(self, tokens: Array, labels: Array) -> Dict[str, float]:
        """One training round: every client acts as initiator once (paper §III-B3).

        tokens/labels: [S, M, mb, seq] per-client local data for this round.
        """
        losses = []
        for owner in range(self.S):
            boundary = self._boundary_at(self.step)
            loss = self._iteration(owner, boundary, tokens, labels)
            losses.append(loss)
            self.step += 1
        return {"loss": float(jnp.mean(jnp.array(losses))),
                "boundary": self._boundary_at(self.step - 1),
                "step": self.step}

    def _iteration(self, owner: int, boundary: int, tokens, labels) -> float:
        fn = self._fn(owner, boundary)
        loss, (g_ad, g_hd) = fn(self.stage_blocks, self.shared, tokens, labels)

        lr = self.tc.learning_rate
        F = frozen_stage_count(self.spans, boundary)
        # stage-row mask: frozen stages' adapters never move
        def upd_ad(g, m, v, p):
            stage_ids = jnp.arange(self.S).reshape(
                (self.S,) + (1,) * (p.ndim - 1))
            mask = (stage_ids >= F).astype(jnp.float32)
            return adamw.leaf_update(g, m, v, p, lr=lr, tc=self.tc, mask=mask)

        trip = jax.tree.map(upd_ad, g_ad, self.m_ad, self.v_ad,
                            self.stage_blocks["adapter"])
        is_t = lambda x: isinstance(x, tuple)
        self.m_ad = jax.tree.map(lambda t: t[0], trip, is_leaf=is_t)
        self.v_ad = jax.tree.map(lambda t: t[1], trip, is_leaf=is_t)
        new_ad = jax.tree.map(lambda t: t[2], trip, is_leaf=is_t)
        self.stage_blocks = {**self.stage_blocks, "adapter": new_ad}

        trip_h = jax.tree.map(
            lambda g, m, v, p: adamw.leaf_update(g, m, v, p, lr=lr, tc=self.tc),
            g_hd, self.m_hd, self.v_hd, self.shared["head"])
        self.m_hd = jax.tree.map(lambda t: t[0], trip_h, is_leaf=is_t)
        self.v_hd = jax.tree.map(lambda t: t[1], trip_h, is_leaf=is_t)
        self.shared = {**self.shared,
                       "head": jax.tree.map(lambda t: t[2], trip_h, is_leaf=is_t)}
        return float(loss)

    # ------------------------------------------------------------------
    def export_params(self) -> Dict[str, Any]:
        return pl.unstack(self.stage_blocks, self.cfg, self._params_rest,
                          self.shared, spans=self.spans)
