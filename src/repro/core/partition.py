"""Coordinator-side layer assignment (RingAda Algorithm 1, line 1).

Given per-device compute speeds and memory budgets, assign each device a
*contiguous* span of transformer blocks so the bottleneck stage time is minimized
(the paper's example assignment 4:5:2:3 arises from heterogeneous devices).

Solved by binary search over the bottleneck time + greedy feasibility check —
optimal for contiguous partitions with monotone per-device costs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class DeviceProfile:
    """State information a client uploads at initialization: (R_u, C_comp, C_mem)."""

    compute_speed: float          # relative FLOP/s (1.0 = reference device)
    memory_mb: float              # DRAM budget
    link_mbps: float = 1000.0     # egress rate to the next ring neighbour


def assign_layers(layer_costs: Sequence[float], layer_mem_mb: Sequence[float],
                  devices: Sequence[DeviceProfile]) -> List[Tuple[int, int]]:
    """Return [(begin, end)] block spans per device (end exclusive), in ring order.

    ``layer_costs``: per-block forward+backward time on the reference device.
    Minimizes max_u (sum of assigned costs / speed_u) s.t. memory fits.
    """
    n, U = len(layer_costs), len(devices)
    assert n >= U, "fewer blocks than devices"

    def feasible(T: float) -> Optional[List[Tuple[int, int]]]:
        spans, i = [], 0
        for u, dev in enumerate(devices):
            t = m = 0.0
            j = i
            remaining_devices = U - u - 1
            while j < n and n - j > remaining_devices:
                dt = layer_costs[j] / dev.compute_speed
                dm = layer_mem_mb[j]
                if t + dt > T or m + dm > dev.memory_mb:
                    break
                t, m = t + dt, m + dm
                j += 1
            if j == i:                       # must take at least one block
                if layer_mem_mb[i] > dev.memory_mb:
                    return None
                j = i + 1
            spans.append((i, j))
            i = j
        return spans if i == n else None

    lo = max(c / max(d.compute_speed for d in devices) for c in layer_costs)
    hi = sum(layer_costs) / min(d.compute_speed for d in devices)
    best = feasible(hi)
    if best is None:
        raise ValueError("memory budgets cannot hold the model")
    for _ in range(64):
        mid = (lo + hi) / 2
        got = feasible(mid)
        if got is not None:
            best, hi = got, mid
        else:
            lo = mid
    return best


def uniform_assignment(n_blocks: int, n_stages: int) -> List[Tuple[int, int]]:
    """Even split used by the SPMD shard_map pipeline (requires divisibility)."""
    assert n_blocks % n_stages == 0, (n_blocks, n_stages)
    per = n_blocks // n_stages
    return [(i * per, (i + 1) * per) for i in range(n_stages)]
