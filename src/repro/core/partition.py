"""Coordinator-side layer assignment (RingAda Algorithm 1, line 1).

Given per-device compute speeds and memory budgets, assign each device a
*contiguous* span of transformer blocks so the bottleneck stage time is minimized
(the paper's example assignment 4:5:2:3 arises from heterogeneous devices).

Solved by binary search over the bottleneck time + greedy feasibility check —
optimal for contiguous partitions with monotone per-device costs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple, Union

Span = Tuple[int, int]


@dataclass(frozen=True)
class DeviceProfile:
    """State information a client uploads at initialization: (R_u, C_comp, C_mem)."""

    compute_speed: float          # relative FLOP/s (1.0 = reference device)
    memory_mb: float              # DRAM budget
    link_mbps: float = 1000.0     # egress rate to the next ring neighbour

    def __post_init__(self):
        # A NaN speed poisons assign_layers' binary search silently (every
        # comparison is False) and a non-positive one inverts it — validate
        # at construction so a bad profile can never reach the partitioner.
        if math.isnan(self.compute_speed) or self.compute_speed <= 0:
            raise ValueError(
                f"compute_speed must be a positive finite number, got "
                f"{self.compute_speed!r}")
        if math.isnan(self.memory_mb) or self.memory_mb <= 0:
            raise ValueError(
                f"memory_mb must be positive (inf = unconstrained), got "
                f"{self.memory_mb!r}")
        if not (self.link_mbps > 0):         # catches NaN and <= 0 at once
            raise ValueError(
                f"link_mbps must be > 0, got {self.link_mbps!r}")

    def slowed(self, factor: float) -> "DeviceProfile":
        """This device, ``factor``x slower (churn's slowdown event)."""
        if math.isnan(factor) or factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor!r}")
        return DeviceProfile(compute_speed=self.compute_speed / factor,
                             memory_mb=self.memory_mb,
                             link_mbps=self.link_mbps)


def assign_layers(layer_costs: Sequence[float], layer_mem_mb: Sequence[float],
                  devices: Sequence[DeviceProfile]) -> List[Tuple[int, int]]:
    """Return [(begin, end)] block spans per device (end exclusive), in ring order.

    ``layer_costs``: per-block forward+backward time on the reference device.
    Minimizes max_u (sum of assigned costs / speed_u) s.t. memory fits.
    """
    n, U = len(layer_costs), len(devices)
    assert n >= U, "fewer blocks than devices"

    def feasible(T: float) -> Optional[List[Tuple[int, int]]]:
        spans, i = [], 0
        for u, dev in enumerate(devices):
            t = m = 0.0
            j = i
            remaining_devices = U - u - 1
            while j < n and n - j > remaining_devices:
                dt = layer_costs[j] / dev.compute_speed
                dm = layer_mem_mb[j]
                if t + dt > T or m + dm > dev.memory_mb:
                    break
                t, m = t + dt, m + dm
                j += 1
            if j == i:                       # must take at least one block
                if layer_mem_mb[i] > dev.memory_mb:
                    return None
                j = i + 1
            spans.append((i, j))
            i = j
        return spans if i == n else None

    lo = max(c / max(d.compute_speed for d in devices) for c in layer_costs)
    hi = sum(layer_costs) / min(d.compute_speed for d in devices)
    best = feasible(hi)
    if best is None:
        raise ValueError("memory budgets cannot hold the model")
    for _ in range(64):
        mid = (lo + hi) / 2
        got = feasible(mid)
        if got is not None:
            best, hi = got, mid
        else:
            lo = mid
    return best


def uniform_assignment(n_blocks: int, n_stages: int) -> List[Tuple[int, int]]:
    """Balanced contiguous split used as the default stage layout.

    When ``n_blocks`` divides evenly this is the classic ``L/S``-per-stage
    split; otherwise it falls back to the most balanced ragged split (the
    first ``n_blocks % n_stages`` stages take one extra block) instead of
    crashing — the ragged-span pipeline executes either layout.
    """
    assert 0 < n_stages <= n_blocks, (n_blocks, n_stages)
    base, rem = divmod(n_blocks, n_stages)
    spans, i = [], 0
    for u in range(n_stages):
        j = i + base + (1 if u < rem else 0)
        spans.append((i, j))
        i = j
    return spans


# ---------------------------------------------------------------------------
# Span-layout helpers (shared by pipeline / executor / simulator / tests)
# ---------------------------------------------------------------------------


def normalize_spans(spans: Union[Sequence[Span], Sequence[int]],
                    n_blocks: Optional[int] = None) -> Tuple[Span, ...]:
    """Canonicalize a span layout: accepts [(begin, end), ...] or a sizes
    list like [4, 5, 2, 3]; validates contiguity/coverage.  Returns a tuple
    of (begin, end) pairs (hashable — the activation cache's layout key)."""
    spans = list(spans)
    assert spans, "empty span layout"
    if spans and not isinstance(spans[0], (tuple, list)):
        sizes = [int(s) for s in spans]
        out, i = [], 0
        for sz in sizes:
            out.append((i, i + sz))
            i += sz
        spans = out
    spans = [(int(b), int(e)) for b, e in spans]
    prev = 0
    for b, e in spans:
        if b != prev or e <= b:
            raise ValueError(
                f"span layout {spans} is not a contiguous cover: span "
                f"({b}, {e}) should start at {prev} and be non-empty")
        prev = e
    if n_blocks is not None and prev != n_blocks:
        raise ValueError(
            f"span layout {spans} covers {prev} blocks, model has {n_blocks}")
    return tuple(spans)


def span_sizes(spans: Sequence[Span]) -> Tuple[int, ...]:
    return tuple(e - b for b, e in spans)


def span_boundaries(spans: Sequence[Span]) -> Tuple[int, ...]:
    """Cumulative block counts [0, |s0|, |s0|+|s1|, ..., n_blocks] — the only
    boundaries (frozen blocks from the bottom) a given layout can realize."""
    return (0,) + tuple(e for _, e in spans)


def frozen_stage_count(spans: Sequence[Span], boundary: int) -> int:
    """Number of fully-frozen stages for a span-ALIGNED boundary.

    Raises when the boundary does not fall on a span edge — callers align
    first via :func:`align_boundary`.
    """
    cum = span_boundaries(spans)
    if boundary not in cum:
        raise ValueError(
            f"boundary {boundary} is not span-aligned for layout "
            f"{list(spans)} (alignable boundaries: {list(cum)})")
    return cum.index(boundary)


def align_boundary(spans: Sequence[Span], boundary: int) -> int:
    """Round a raw (block-granular) boundary DOWN to the nearest span edge —
    fewer frozen blocks, never more (the terminator device owns the span the
    raw boundary falls inside, so its whole span stays hot)."""
    return max(c for c in span_boundaries(spans) if c <= boundary)


def spans_from_profiles(n_blocks: int, devices: Sequence[DeviceProfile], *,
                        layer_costs: Optional[Sequence[float]] = None,
                        layer_mem_mb: Optional[Sequence[float]] = None,
                        ) -> Tuple[Span, ...]:
    """Speed-weighted span layout for a heterogeneous ring (Algorithm 1).

    Default per-block costs are uniform (1.0) and memory unconstrained —
    the assignment then minimizes ``max_u span_u / speed_u``, which is the
    paper's 4:5:2:3 example for speeds skewed toward the middle devices.
    """
    costs = list(layer_costs) if layer_costs is not None else [1.0] * n_blocks
    mems = (list(layer_mem_mb) if layer_mem_mb is not None
            else [0.0] * n_blocks)
    assert len(costs) == len(mems) == n_blocks
    return normalize_spans(assign_layers(costs, mems, devices), n_blocks)


def parse_device_profiles(speeds: Iterable[Union[float, DeviceProfile]],
                          ) -> List[DeviceProfile]:
    """Coerce a mixed list of speeds / profiles (e.g. the CLI's
    ``--device-speeds 1.0,0.5,2.0,1.0``) into DeviceProfile objects."""
    out = []
    for s in speeds:
        if isinstance(s, DeviceProfile):
            out.append(s)
        else:
            sp = float(s)
            if sp <= 0:
                raise ValueError(f"device speed must be > 0, got {sp}")
            out.append(DeviceProfile(compute_speed=sp, memory_mb=float("inf")))
    if not out:
        raise ValueError("empty device-profile list")
    return out
