"""Elasticity: straggler detection + chaos-event parsing for the ring.

RingAda's fleet is edge devices — phones throttle, tablets drop off WiFi,
chargers get unplugged.  The coordinator-side pieces that keep the ring
useful through that churn live here:

  * :class:`StragglerDetector` — watches per-round per-stage wall times,
    re-fits each device's ``compute_speed`` with an EWMA, and proposes a
    speed-reprofiled span layout (Algorithm 1 over the EWMA fleet) when the
    predicted bottleneck improvement clears a hysteresis threshold for
    ``patience`` consecutive rounds.  The hysteresis + the fact that a
    repartition equalizes stage times (driving the predicted improvement
    back to ~1x) mean a stable skewed mesh triggers at most ONE
    repartition — no flapping (pinned in tests/test_elastic.py).
  * :func:`parse_chaos_events` — the CLI's ``--chaos round:event:device``
    fault-injection specs, validated into ``ChurnEvent``\\ s.

The recovery mechanics themselves (``shrink``/``grow``/``repartition``)
live on ``RingExecutor``; the simulated twin lives in ``core/simulator.py``
(``ChurnEvent`` replay + ``predict_recovery``).
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.partition import (DeviceProfile, Span, normalize_spans,
                                  span_sizes, spans_from_profiles)
from repro.core.simulator import CHURN_KINDS, ChurnEvent


class StragglerDetector:
    """EWMA speed re-profiler with a hysteresis-gated repartition trigger.

    ``observe(spans, stage_times)`` feeds one round's measured per-stage
    wall times; each stage's implied speed (``span_size / stage_time``,
    span size being the SPMD per-tick work unit) updates that device's
    EWMA estimate.  ``propose(spans)`` then compares the current layout's
    predicted bottleneck against the best layout for the EWMA fleet and
    returns the new spans only when

        bottleneck(current) / bottleneck(best)  >=  threshold

    has held for ``patience`` consecutive observations — one slow round
    (GC pause, transient contention) never triggers a restack.
    """

    def __init__(self, profiles: Sequence[DeviceProfile], n_blocks: int, *,
                 alpha: float = 0.5, threshold: float = 1.2,
                 patience: int = 2):
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if threshold < 1.0:
            raise ValueError(f"threshold must be >= 1.0, got {threshold}")
        self.profiles: List[DeviceProfile] = list(profiles)
        self.speeds: List[float] = [p.compute_speed for p in self.profiles]
        self.n_blocks = n_blocks
        self.alpha = alpha
        self.threshold = threshold
        self.patience = max(1, patience)
        self.streak = 0                  # consecutive over-threshold rounds
        self.repartitions = 0            # proposals actually returned

    # -- fleet membership (shrink/grow keep the EWMA state aligned) --------

    def remove(self, idx: int) -> None:
        del self.profiles[idx]
        del self.speeds[idx]
        self.streak = 0

    def insert(self, idx: int, profile: DeviceProfile) -> None:
        self.profiles.insert(idx, profile)
        self.speeds.insert(idx, profile.compute_speed)
        self.streak = 0

    @property
    def fleet(self) -> List[DeviceProfile]:
        """Current EWMA-refit profiles (speed updated, memory/link kept)."""
        return [DeviceProfile(compute_speed=s, memory_mb=p.memory_mb,
                              link_mbps=p.link_mbps)
                for p, s in zip(self.profiles, self.speeds)]

    # -- observation + trigger --------------------------------------------

    def observe(self, spans: Sequence[Span],
                stage_times: Sequence[float]) -> None:
        spans = normalize_spans(spans)
        if len(spans) != len(self.speeds) or len(stage_times) != len(spans):
            raise ValueError(
                f"observation shape mismatch: {len(spans)} spans / "
                f"{len(stage_times)} stage times vs {len(self.speeds)} "
                f"tracked devices")
        for u, (sz, t) in enumerate(zip(span_sizes(spans), stage_times)):
            if not (t > 0):              # skip degenerate/absent timings
                continue
            implied = sz / t
            self.speeds[u] = ((1 - self.alpha) * self.speeds[u]
                              + self.alpha * implied)

    def bottleneck(self, spans: Sequence[Span]) -> float:
        """Predicted round bottleneck (max stage time) under EWMA speeds."""
        spans = normalize_spans(spans)
        return max(sz / s for sz, s in zip(span_sizes(spans), self.speeds))

    def propose(self, spans: Sequence[Span]) -> Optional[Tuple[Span, ...]]:
        """Return a better layout, or None (hysteresis not cleared)."""
        spans = normalize_spans(spans, self.n_blocks)
        best = spans_from_profiles(self.n_blocks, self.fleet)
        if best == spans:
            self.streak = 0
            return None
        cur_t, best_t = self.bottleneck(spans), self.bottleneck(best)
        if best_t <= 0 or cur_t / best_t < self.threshold:
            self.streak = 0
            return None
        self.streak += 1
        if self.streak < self.patience:
            return None
        self.streak = 0
        self.repartitions += 1
        return best


def parse_chaos_events(specs: Iterable[str]) -> Tuple[ChurnEvent, ...]:
    """Parse CLI ``--chaos`` specs: ``"round:event:device[:factor]"``.

    e.g. ``"3:crash:2"`` (kill device 2 before round 3) or
    ``"5:slowdown:1:4.0"`` (device 1 becomes 4x slower before round 5).
    Raises ``ValueError`` naming the offending spec.
    """
    events = []
    for spec in specs:
        parts = str(spec).split(":")
        if len(parts) not in (3, 4):
            raise ValueError(
                f"bad --chaos spec {spec!r}: want 'round:event:device' or "
                f"'round:event:device:factor'")
        try:
            rnd, dev = int(parts[0]), int(parts[2])
            factor = float(parts[3]) if len(parts) == 4 else 2.0
        except ValueError as e:
            raise ValueError(f"bad --chaos spec {spec!r}: {e}") from None
        kind = parts[1].lower()
        if kind not in CHURN_KINDS:
            raise ValueError(
                f"bad --chaos spec {spec!r}: unknown event {kind!r} "
                f"(one of {CHURN_KINDS})")
        events.append(ChurnEvent(round=rnd, kind=kind, device=dev,
                                 factor=factor))
    return tuple(sorted(events, key=lambda ev: ev.round))
