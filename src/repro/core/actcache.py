"""Boundary-activation cache: device-resident reuse of the frozen trunk.

RingAda's unfreeze schedule is monotone top-down, so every layer below the
boundary is frozen and Phase A (the ``M + F - 1`` forward-only ticks through
the frozen trunk, run once per owner-iteration) recomputes activations that
are bit-identical across epochs until the boundary drops.  This module stores
those stage-``F`` boundary activations so the fused executor can enter the
pipeline directly at stage ``F`` on steady-state rounds (see
``core/pipeline.py``'s module docstring for the full design).

Storage is a single preallocated **donated ring buffer** on device:

  * one array ``[capacity, *entry_shape]``, allocated on first ``put`` with
    the caller-supplied sharding (the executor passes ``P(None, 'stage')`` so
    rows stay stage-sharded exactly like the activations they hold),
  * writes are a jitted ``dynamic_update_index`` with the buffer donated —
    the XLA update aliases in place, no second copy of the buffer ever lives,
  * reads never slice on the host: consumers take ``(buffer, row_index)`` and
    dynamic-index inside their own executable, so a cache hit costs zero
    host<->device traffic and zero recompilation (the row index is traced).

Compressed entries (``dtype=``): edge memory is the binding constraint —
``capacity`` caps well below ``slots_per_epoch`` on realistic configs — so
the buffer can store entries below capture precision, 2-4x more entries per
byte:

  * ``'native'`` (default) — store bits exactly as captured (a bf16 model's
    activations stay bf16; lossless),
  * ``'f32'`` — upcast to float32 (lossless for bf16/f32 sources; the
    full-precision reference mode),
  * ``'bf16'`` — store bfloat16 (lossless when the model computes in bf16,
    ~3 decimal digits otherwise; half the bytes of f32),
  * ``'int8'`` — symmetric per-row int8 (the same ``_quant``/``_dequant``
    scheme as ``models/blocks.py``'s KV cache: one f32 scale per trailing
    ``d_model`` row, stored in a **scale sidecar** buffer alongside the ring
    buffer; ~quarter the bytes of f32 at ~0.4% max row error).

Quantization happens inside the donated writer jit on ``put``; consumers
dequantize inside their own executable via :func:`dequantize` (the executor
bakes the static ``dtype`` into its cached executable, so a hit still costs
zero host<->device traffic).  ``stats()`` reports the realized bytes/entry
so hit-rate-per-byte is measurable (``benchmarks/pipeline_bench.py``).

Keys are ``(batch_slot, boundary)``.  Eviction is LRU over a fixed number of
rows (``capacity``); free rows are tracked in an O(1) free list (steady-state
``put`` never scans the capacity).  Because the schedule is monotone
(enforced by ``core/unfreeze.py``), a boundary drop makes *every* entry
permanently unreachable; ``invalidate()`` drops them all in one step and
counts the event.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Any, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

CACHE_DTYPES = ("native", "f32", "bf16", "int8")

_STORAGE = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}


def quantize(entry: Array, dtype: str) -> Tuple[Array, Optional[Array]]:
    """Entry -> (stored, scales-or-None) under cache dtype ``dtype``.

    ``int8`` uses symmetric per-row quantization over the trailing (feature)
    axis — the ``models/blocks.py`` KV-cache scheme — with f32 scales.
    Traceable (runs inside the donated writer jit).
    """
    if dtype == "int8":
        tf = entry.astype(jnp.float32)
        s = jnp.max(jnp.abs(tf), axis=-1, keepdims=True)
        s = jnp.maximum(s, 1e-6) / 127.0
        q = jnp.clip(jnp.round(tf / s), -127, 127).astype(jnp.int8)
        return q, s
    if dtype == "native":
        return entry, None
    return entry.astype(_STORAGE[dtype]), None


def dequantize(stored: Array, scales: Optional[Array], dtype: str,
               out_dtype) -> Array:
    """Inverse of :func:`quantize`, cast to the consumer's compute dtype.

    Traceable — the executor's cached executable calls this on the
    dynamically-indexed row so dequantization stays on device.  ``'native'``
    entries pass through bit-exact (no cast).
    """
    if dtype == "int8":
        return (stored.astype(jnp.float32) * scales).astype(out_dtype)
    if dtype == "native":
        return stored
    return stored.astype(out_dtype)


def storage_dtype(dtype: str, src_dtype) -> Any:
    """The on-buffer dtype for cache mode ``dtype`` given the captured
    entries' dtype."""
    if dtype == "native":
        return jnp.dtype(src_dtype)
    return jnp.dtype(_STORAGE[dtype])


class ActivationCache:
    """LRU cache of boundary activations in one donated device ring buffer.

    ``capacity`` is the number of entries (batch slots) held at once;
    ``capacity == 0`` disables the cache (every ``index_of`` misses, ``put``
    is a no-op).  ``dtype`` selects the storage precision (see module
    docstring); ``sharding`` (optional) is applied to the buffer (and the
    int8 scale sidecar) when first allocated — pass the row sharding extended
    with a leading replicated axis, e.g. ``NamedSharding(mesh, P(None,
    'stage'))``.

    ``layout`` (optional, any hashable — the executor passes its span-layout
    tuple) binds the cached bits to the stage layout that produced them:
    entries hold STAGE-LOCAL shards of the stage-``F`` boundary activations,
    so after a repartition the same bytes would be injected at a different
    block index.  ``set_layout`` flushes the whole cache whenever the layout
    changes (counted as an invalidation event, like a boundary drop).
    """

    def __init__(self, capacity: int, *, dtype: str = "native",
                 sharding: Optional[Any] = None,
                 layout: Optional[Any] = None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if dtype not in CACHE_DTYPES:
            raise ValueError(f"dtype must be one of {CACHE_DTYPES}, "
                             f"got {dtype!r}")
        self.capacity = capacity
        self.dtype = dtype
        self.sharding = sharding
        self.layout = layout
        self._buf: Optional[Array] = None
        self._scales: Optional[Array] = None
        self._rows: "OrderedDict[Hashable, int]" = OrderedDict()  # key -> row
        # O(1) free-row bookkeeping: rows not in _rows.values(); pop() beats
        # the old O(capacity) first-free scan at large capacities.
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._entry_shape: Optional[Tuple[int, ...]] = None
        self._src_dtype = None
        self._writer = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0       # boundary-drop (or manual) clear events
        self.bypasses = 0            # entries refused because they don't fit

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    @property
    def buffer(self) -> Array:
        """The backing ring buffer (for consumers that index rows on device)."""
        assert self._buf is not None, "cache is empty — no buffer yet"
        return self._buf

    @property
    def src_dtype(self):
        """Dtype of the captured (pre-quantization) entries; None until the
        first ``put`` fixes it."""
        return self._src_dtype

    @property
    def scales(self) -> Optional[Array]:
        """The int8 scale sidecar ([capacity, *entry_shape[:-1], 1], f32);
        None for non-int8 dtypes."""
        return self._scales

    def compatible(self, shape: Tuple[int, ...], dtype=None) -> bool:
        """Can an entry of this (pre-quantization) shape — and source dtype,
        if given — live in the buffer?

        Before the first ``put`` any shape fits; afterwards the buffer is
        fixed and mismatching batches must bypass the cache.
        """
        if self.capacity == 0:
            return False
        if self._entry_shape is None:
            return True
        if tuple(shape) != self._entry_shape:
            return False
        return dtype is None or jnp.dtype(dtype) == self._src_dtype

    # ------------------------------------------------------------------
    def entry_bytes(self) -> Optional[int]:
        """Realized bytes per entry (buffer row + scale-sidecar row); None
        before the first allocation."""
        if self._buf is None:
            return None
        total = self._buf.dtype.itemsize * math.prod(self._buf.shape[1:])
        if self._scales is not None:
            total += (self._scales.dtype.itemsize
                      * math.prod(self._scales.shape[1:]))
        return total

    def _ensure_buffer(self, entry: Array) -> None:
        if self._buf is not None:
            return
        self._entry_shape = tuple(entry.shape)
        self._src_dtype = jnp.dtype(entry.dtype)
        store_dt = storage_dtype(self.dtype, self._src_dtype)
        shape = (self.capacity,) + self._entry_shape

        def alloc(s, dt):
            if self.sharding is not None:
                # allocate directly sharded — never materialize the whole
                # buffer on one device (it may only fit stage-sharded)
                return jax.jit(lambda: jnp.zeros(s, dt),
                               out_shardings=self.sharding)()
            return jnp.zeros(s, dt)

        self._buf = alloc(shape, store_dt)
        out_shardings = self.sharding if self.sharding is not None else None
        if self.dtype == "int8":
            self._scales = alloc(shape[:-1] + (1,), jnp.float32)

            def write(b, sb, v, i):
                q, s = quantize(v, "int8")
                return (lax.dynamic_update_index_in_dim(b, q, i, 0),
                        lax.dynamic_update_index_in_dim(sb, s, i, 0))

            self._writer = jax.jit(
                write, donate_argnums=(0, 1),
                out_shardings=(out_shardings, out_shardings))
        else:
            dt = self.dtype

            def write(b, v, i):
                q, _ = quantize(v, dt)
                return lax.dynamic_update_index_in_dim(b, q, i, 0)

            self._writer = jax.jit(write, donate_argnums=(0,),
                                   out_shardings=out_shardings)

    def put(self, key: Hashable, entry: Array) -> bool:
        """Insert ``entry`` under ``key`` (evicting LRU if full).

        Quantizes to the cache dtype inside the donated writer jit.  Returns
        False (and counts a bypass) when the entry cannot live in the buffer
        — capacity 0, or a shape/source-dtype mismatch with the allocated
        buffer (the batch doesn't fit).  The caller falls back to the
        uncached path; nothing breaks.
        """
        if not self.compatible(entry.shape, entry.dtype):
            self.bypasses += 1
            return False
        self._ensure_buffer(entry)
        if key in self._rows:
            row = self._rows.pop(key)
        elif len(self._rows) >= self.capacity:
            _, row = self._rows.popitem(last=False)      # evict LRU
            self.evictions += 1
        else:
            row = self._free.pop()                       # O(1), never scans
        if self.dtype == "int8":
            self._buf, self._scales = self._writer(
                self._buf, self._scales, entry, row)
        else:
            self._buf = self._writer(self._buf, entry, row)
        self._rows[key] = row
        return True

    def index_of(self, key: Hashable) -> Optional[int]:
        """Buffer row for ``key`` (None on miss). Counts hit/miss, bumps LRU."""
        row = self._rows.get(key)
        if row is None:
            self.misses += 1
            return None
        self._rows.move_to_end(key)
        self.hits += 1
        return row

    # ------------------------------------------------------------------
    def set_layout(self, layout: Any) -> int:
        """Bind the cache to a (new) stage layout, flushing it on change.

        A span-layout change moves the boundary between frozen trunk and hot
        region across devices: every held entry was captured as a stage-local
        shard of the OLD layout's stage-``F`` inputs and can never be valid
        again — same contract as a boundary drop, whole-cache invalidation.
        Setting the same layout is a no-op.  Returns the number of entries
        dropped.
        """
        if layout == self.layout:
            return 0
        self.layout = layout
        return self.invalidate()

    # ------------------------------------------------------------------
    def invalidate(self) -> int:
        """Drop every entry (boundary drop: all keys are now unreachable).

        The buffer itself is kept — same shapes, the rows are just dead —
        so re-capture after a drop reuses the allocation.  Returns the number
        of entries dropped; counts one invalidation event if any were live.
        """
        n = len(self._rows)
        self._rows.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        if n:
            self.invalidations += 1
        return n

    # ------------------------------------------------------------------
    def rebind(self, *, sharding: Optional[Any] = None, layout: Any) -> int:
        """Re-home the cache after a ring-geometry change (shrink/grow).

        ``set_layout`` handles same-S repartitions (the buffer's shapes
        survive, only the keys die), but a shrink/grow changes S and the
        entry shape itself carries S (``[S_stage, S_owner, M, mb, seq, D]``)
        AND the buffer's sharding mesh — so the allocation cannot be reused.
        Drops the buffer, writer, and shape/dtype bindings (the next ``put``
        re-allocates at the new geometry under ``sharding``) while KEEPING
        the hit/miss/eviction counters: recovery hit-rate accounting spans
        the shrink.  Returns the number of entries dropped; counts one
        invalidation event if any were live.
        """
        n = len(self._rows)
        self._rows.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        if n:
            self.invalidations += 1
        self.sharding = sharding if sharding is not None else self.sharding
        self.layout = layout
        self._buf = None
        self._scales = None
        self._writer = None
        self._entry_shape = None
        self._src_dtype = None
        return n

    # ------------------------------------------------------------------
    def invalidate_tenant(self, tenant: Hashable) -> int:
        """Drop only the entries whose key's FIRST component is ``tenant``.

        The multi-tenant executor keys entries ``(tenant, slot, boundary)``;
        a single tenant's adapter import (or any per-tenant staleness) kills
        only that tenant's partition — its neighbors' rows, LRU order, and
        hit-rates are untouched.  The freed buffer rows return to the free
        list for reuse.  Returns the number of entries dropped; counts one
        invalidation event if any were live.
        """
        dead = [k for k in self._rows
                if isinstance(k, tuple) and len(k) > 0 and k[0] == tenant]
        for k in dead:
            self._free.append(self._rows.pop(k))
        if dead:
            self.invalidations += 1
        return len(dead)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        eb = self.entry_bytes()
        return {
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "cache_hit_rate": self.hits / total if total else 0.0,
            "cache_evictions": self.evictions,
            "cache_invalidations": self.invalidations,
            "cache_bypasses": self.bypasses,
            "cache_entries": len(self._rows),
            "cache_capacity": self.capacity,
            "cache_dtype": self.dtype,
            "cache_bytes_per_entry": eb if eb is not None else 0,
            "cache_buffer_bytes": (eb or 0) * self.capacity,
        }
