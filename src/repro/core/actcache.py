"""Boundary-activation cache: device-resident reuse of the frozen trunk.

RingAda's unfreeze schedule is monotone top-down, so every layer below the
boundary is frozen and Phase A (the ``M + F - 1`` forward-only ticks through
the frozen trunk, run once per owner-iteration) recomputes activations that
are bit-identical across epochs until the boundary drops.  This module stores
those stage-``F`` boundary activations so the fused executor can enter the
pipeline directly at stage ``F`` on steady-state rounds (see
``core/pipeline.py``'s module docstring for the full design).

Storage is a single preallocated **donated ring buffer** on device:

  * one array ``[capacity, *entry_shape]``, allocated on first ``put`` with
    the caller-supplied sharding (the executor passes ``P(None, 'stage')`` so
    rows stay stage-sharded exactly like the activations they hold),
  * writes are a jitted ``dynamic_update_index`` with the buffer donated —
    the XLA update aliases in place, no second copy of the buffer ever lives,
  * reads never slice on the host: consumers take ``(buffer, row_index)`` and
    dynamic-index inside their own executable, so a cache hit costs zero
    host<->device traffic and zero recompilation (the row index is traced).

Keys are ``(batch_slot, boundary)``.  Eviction is LRU over a fixed number of
rows (``capacity``).  Because the schedule is monotone (enforced by
``core/unfreeze.py``), a boundary drop makes *every* entry permanently
unreachable; ``invalidate()`` drops them all in one step and counts the event.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


class ActivationCache:
    """LRU cache of boundary activations in one donated device ring buffer.

    ``capacity`` is the number of entries (batch slots) held at once;
    ``capacity == 0`` disables the cache (every ``index_of`` misses, ``put``
    is a no-op).  ``sharding`` (optional) is applied to the buffer when it is
    first allocated — pass the row sharding extended with a leading
    replicated axis, e.g. ``NamedSharding(mesh, P(None, 'stage'))``.
    """

    def __init__(self, capacity: int, *, sharding: Optional[Any] = None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self.sharding = sharding
        self._buf: Optional[Array] = None
        self._rows: "OrderedDict[Hashable, int]" = OrderedDict()  # key -> row
        self._entry_shape: Optional[Tuple[int, ...]] = None
        self._entry_dtype = None
        self._writer = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0       # boundary-drop (or manual) clear events
        self.bypasses = 0            # entries refused because they don't fit

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    @property
    def buffer(self) -> Array:
        """The backing ring buffer (for consumers that index rows on device)."""
        assert self._buf is not None, "cache is empty — no buffer yet"
        return self._buf

    def compatible(self, shape: Tuple[int, ...], dtype=None) -> bool:
        """Can an entry of this shape (and dtype, if given) live in the buffer?

        Before the first ``put`` any shape fits; afterwards the buffer is
        fixed and mismatching batches must bypass the cache.
        """
        if self.capacity == 0:
            return False
        if self._entry_shape is None:
            return True
        if tuple(shape) != self._entry_shape:
            return False
        return dtype is None or jnp.dtype(dtype) == self._entry_dtype

    # ------------------------------------------------------------------
    def _ensure_buffer(self, entry: Array) -> None:
        if self._buf is not None:
            return
        self._entry_shape = tuple(entry.shape)
        self._entry_dtype = jnp.dtype(entry.dtype)
        shape = (self.capacity,) + self._entry_shape
        if self.sharding is not None:
            # allocate directly sharded — never materialize the whole buffer
            # on one device (it may only fit stage-sharded)
            self._buf = jax.jit(lambda: jnp.zeros(shape, entry.dtype),
                                out_shardings=self.sharding)()
        else:
            self._buf = jnp.zeros(shape, entry.dtype)
        write = lambda b, v, i: lax.dynamic_update_index_in_dim(b, v, i, 0)
        out_shardings = self.sharding if self.sharding is not None else None
        self._writer = jax.jit(write, donate_argnums=(0,),
                               out_shardings=out_shardings)

    def put(self, key: Hashable, entry: Array) -> bool:
        """Insert ``entry`` under ``key`` (evicting LRU if full).

        Returns False (and counts a bypass) when the entry cannot live in the
        buffer — capacity 0, or a shape/dtype mismatch with the allocated
        buffer (the batch doesn't fit).  The caller falls back to the
        uncached path; nothing breaks.
        """
        if not self.compatible(entry.shape, entry.dtype):
            self.bypasses += 1
            return False
        self._ensure_buffer(entry)
        if key in self._rows:
            row = self._rows.pop(key)
        elif len(self._rows) >= self.capacity:
            _, row = self._rows.popitem(last=False)      # evict LRU
            self.evictions += 1
        else:
            used = set(self._rows.values())
            row = next(r for r in range(self.capacity) if r not in used)
        self._buf = self._writer(self._buf, entry, row)
        self._rows[key] = row
        return True

    def index_of(self, key: Hashable) -> Optional[int]:
        """Buffer row for ``key`` (None on miss). Counts hit/miss, bumps LRU."""
        row = self._rows.get(key)
        if row is None:
            self.misses += 1
            return None
        self._rows.move_to_end(key)
        self.hits += 1
        return row

    # ------------------------------------------------------------------
    def invalidate(self) -> int:
        """Drop every entry (boundary drop: all keys are now unreachable).

        The buffer itself is kept — same shapes, the rows are just dead —
        so re-capture after a drop reuses the allocation.  Returns the number
        of entries dropped; counts one invalidation event if any were live.
        """
        n = len(self._rows)
        self._rows.clear()
        if n:
            self.invalidations += 1
        return n

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "cache_hit_rate": self.hits / total if total else 0.0,
            "cache_evictions": self.evictions,
            "cache_invalidations": self.invalidations,
            "cache_bypasses": self.bypasses,
            "cache_entries": len(self._rows),
            "cache_capacity": self.capacity,
        }
