"""Trace-based discrete-event simulator — the paper's own evaluation methodology.

The paper evaluates RingAda with a trace-driven simulation: per-layer forward and
backward times are profiled once (on real hardware, here: real JAX timings on this
host), stored in a lookup table, scaled by each edge device's relative compute
speed, and the three schemes are replayed by a discrete-event engine:

  * ``single``       — classic adapter fine-tuning on one device (all adapters hot)
  * ``pipe_adapter`` — 1F1B pipeline across U devices, all adapters hot, PipeDream-
                        style weight stashing (multiple in-flight versions)
  * ``ringada``      — pipeline + scheduled top-down unfreezing: backward early-stops
                        at the terminator device; devices whose adapters are all
                        frozen stream forward passes continuously (no 1F1B stall),
                        single weight version (staleness-free by construction)
  * ``ringada_cached`` — RingAda steady state with the frozen-trunk activation
                        cache (core/actcache.py): on cache-hit rounds the frozen
                        devices do NO forward work at all — the terminator reads
                        the boundary activations from its local cache and the
                        pipeline starts there.  Keeps simulated and measured
                        Phase-A-skip speedups comparable.
  * ``ringada_packed`` — RingAda with the packed Phase-A conveyor
                        (core/pipeline.py ``ring_phase_a_packed``): with
                        ``n_owners > 1`` the frozen devices stream ALL
                        owner-iterations' microbatches back-to-back (no
                        per-owner fill/drain bubble); only the hot region
                        serializes per owner.  Validates the
                        ``S*M + F - 1`` / ``(S-1)*(F-1)`` closed forms.

Outputs per scheme: wall-clock time per epoch / to convergence, per-device peak
memory (weights + adapters + optimizer + activation stashes + weight stashes) —
the quantities of the paper's Table I and Fig. 3(b).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.partition import (DeviceProfile, align_boundary,
                                  frozen_stage_count, normalize_spans,
                                  span_sizes, spans_from_profiles,
                                  uniform_assignment)

CHURN_KINDS = ("crash", "leave", "slowdown", "join")


@dataclass(frozen=True)
class LayerProfile:
    """Per-block lookup-table entry (reference device, seconds / MB)."""

    fwd_s: float
    bwd_s: float                 # dgrad + adapter wgrad when the adapter is hot
    act_mb: float                # residuals that must be stashed for backward
    weight_mb: float
    adapter_mb: float
    # activation tensor that crosses the device boundary per microbatch
    boundary_mb: float


@dataclass(frozen=True)
class SimConfig:
    n_layers: int
    n_devices: int
    n_microbatches: int = 8       # in-flight per round
    head_fwd_s: float = 0.0
    head_bwd_s: float = 0.0
    head_mb: float = 0.0
    embed_mb: float = 0.0


@dataclass
class SimResult:
    time_per_round_s: float
    peak_memory_mb: Dict[int, float]     # per device
    device_busy_s: Dict[int, float]
    bubbles_s: float

    @property
    def max_memory_mb(self) -> float:
        return max(self.peak_memory_mb.values())


@dataclass(frozen=True)
class ChurnEvent:
    """One fleet-membership/speed change, applied BEFORE round ``round``.

    ``kind``:
      * ``'crash'`` / ``'leave'`` — device ``device`` (an index into the
        CURRENT fleet) drops out; its span is reassigned over the survivors.
        The two are priced identically here (an orderly leave and a crash
        both cost a repartition + cache re-capture); executors may treat a
        ``leave`` more gently (drain first) — the simulator is the
        worst-case bound.
      * ``'slowdown'`` — device ``device`` becomes ``factor``x slower
        (thermal throttling, contention); profiles are re-fit and the ring
        repartitions if the assignment changes.
      * ``'join'`` — a device with ``profile`` joins at position ``device``
        (S grows by one).
    """

    round: int
    kind: str
    device: int
    factor: float = 2.0                    # slowdown multiplier (kind-specific)
    profile: Optional[DeviceProfile] = None   # joining device (kind='join')

    def __post_init__(self):
        if self.kind not in CHURN_KINDS:
            raise ValueError(
                f"unknown churn kind {self.kind!r}; expected one of "
                f"{CHURN_KINDS}")
        if self.round < 0 or self.device < 0:
            raise ValueError(f"round/device must be >= 0, got {self}")
        if self.kind == "slowdown" and not (self.factor > 0):
            raise ValueError(f"slowdown factor must be > 0, got {self.factor}")


def apply_churn(devices: Sequence[DeviceProfile], event: ChurnEvent,
                ) -> List[DeviceProfile]:
    """Return the post-event fleet (a new list; input is untouched)."""
    fleet = list(devices)
    if event.device >= len(fleet) + (1 if event.kind == "join" else 0):
        raise ValueError(
            f"churn event {event} targets device {event.device} but the "
            f"fleet has {len(fleet)} devices")
    if event.kind in ("crash", "leave"):
        if len(fleet) <= 1:
            raise ValueError("cannot remove the last device from the ring")
        del fleet[event.device]
    elif event.kind == "slowdown":
        fleet[event.device] = fleet[event.device].slowed(event.factor)
    else:                                           # join
        prof = event.profile or DeviceProfile(compute_speed=1.0,
                                              memory_mb=float("inf"))
        fleet.insert(event.device, prof)
    return fleet


# ---------------------------------------------------------------------------


def _link_time(mb: float, mbps: float) -> float:
    return mb * 8.0 / mbps


def simulate_round(scheme: str, sim: SimConfig, layers: Sequence[LayerProfile],
                   devices: Sequence[DeviceProfile],
                   unfreeze_depth: Optional[int] = None,
                   spans: Optional[List[Tuple[int, int]]] = None,
                   cache_slots: int = 1, n_owners: int = 1) -> SimResult:
    """Simulate one training round (M microbatches through fwd+bwd).

    ``scheme='ringada_cached'`` simulates a steady-state (cache-hit) round:
    frozen devices idle, the terminator injects cached boundary activations.
    ``cache_slots`` sizes the terminator's cache memory (entries held).

    ``n_owners > 1`` simulates a FULL RingAda round — ``n_owners``
    initiator-iterations of M microbatches each.  The ring schemes then
    differ in how the frozen trunk treats the owner change:

      * ``'ringada'`` — the owner-scan barrier: owner ``o``'s microbatches
        enter the pipeline only after owner ``o-1``'s last backward finished
        (the fused SPMD executor's ``lax.scan`` semantics) — ``n_owners``
        separate fill/drain bubbles.
      * ``'ringada_packed'`` — the packed conveyor: frozen devices stream all
        owners' microbatches back-to-back with no barrier (the paper's
        "continuously forward consecutive batches"); only the HOT region
        still serializes per owner (its adapters update between owners).
        With unit-cost frozen stages this reproduces the
        ``pipeline_tick_counts(packed=True)`` closed forms exactly — pinned
        in tests/test_simulator.py.
    """
    L, U, M = sim.n_layers, sim.n_devices, sim.n_microbatches
    assert len(layers) == L
    cached = scheme == "ringada_cached"
    packed = scheme == "ringada_packed"
    ring_like = scheme in ("ringada", "ringada_cached", "ringada_packed")
    assert n_owners == 1 or ring_like, \
        "multi-owner rounds are only defined for the ring schemes"

    if scheme == "single":
        dev = devices[0]
        t = 0.0
        for _ in range(M):
            t += (sum(l.fwd_s for l in layers) + sim.head_fwd_s
                  + sim.head_bwd_s + sum(l.bwd_s for l in layers)
                  ) / dev.compute_speed
        mem = (sum(l.weight_mb + l.adapter_mb * 4 for l in layers)
               + sum(l.act_mb for l in layers)           # full activation set
               + sim.head_mb * 4 + sim.embed_mb)
        return SimResult(t, {0: mem}, {0: t}, 0.0)

    spans = spans or uniform_assignment(L, U)
    owner_of = {u: span for u, span in enumerate(spans)}
    depth = L if scheme == "pipe_adapter" else (unfreeze_depth or L)
    lowest_hot = L - depth                     # first block with a hot adapter
    hot_dev = [u for u, (b, e) in enumerate(spans) if e > lowest_hot]
    terminator = min(hot_dev) if hot_dev else U - 1

    def stage_fwd(u):
        b, e = spans[u]
        return sum(layers[i].fwd_s for i in range(b, e)) / devices[u].compute_speed

    def stage_bwd(u):
        b, e = spans[u]
        return sum(layers[i].bwd_s for i in range(max(b, lowest_hot), e)
                   ) / devices[u].compute_speed

    def hop(u):
        b, e = spans[u]
        return _link_time(layers[e - 1].boundary_mb, devices[u].link_mbps)

    # Discrete-event list scheduler. Ops: fwd(j, u) and bwd(j, u) over the
    # global microbatch index j = owner*M + m, with ring dependencies
    # (+ link hop latencies). 1F1B (PipeDream) on hot devices: device u keeps
    # at most W_u = U - u of one owner's microbatches in flight — fwd(j, u)
    # additionally depends on bwd(j - W_u, u). RingAda's frozen devices carry
    # no trainable state, so they stream forwards freely (the paper's
    # "continuously perform the forward pass"): no 1F1B window. Across owner
    # boundaries: the hot region always serializes on the previous owner's
    # last backward (its adapters update between owners); the frozen trunk
    # does too under the scan ('ringada') but streams straight through under
    # the packed conveyor ('ringada_packed'). Devices pick the earliest-ready
    # op, backward-first on ties (standard 1F1B priority).
    dev_free = [0.0] * U
    busy = [0.0] * U
    done: Dict[Tuple[str, int, int], float] = {}
    remaining = []
    N = n_owners * M
    for j in range(N):
        for u in range(U):
            if cached and u < terminator:
                continue          # frozen trunk skipped: activations cached
            remaining.append(("fwd", j, u))
        for u in range(U - 1, terminator - 1, -1):
            remaining.append(("bwd", j, u))

    def ready_time(op) -> Optional[float]:
        kind, j, u = op
        o, m = divmod(j, M)
        if kind == "fwd":
            t = 0.0
            # the terminator's cached round reads boundary activations from
            # its local cache: no upstream forward to wait for
            if u > 0 and not (cached and u == terminator):
                prev = done.get(("fwd", j, u - 1))
                if prev is None:
                    return None
                t = prev + hop(u - 1)
            hot = not (ring_like and u < terminator)
            # owner barrier: everything except a packed frozen device waits
            # for the previous owner-iteration to fully drain
            if o > 0 and not (packed and not hot):
                prevo = done.get(("bwd", o * M - 1, max(u, terminator)))
                if prevo is None:
                    return None
                t = max(t, prevo)
            w = U - u
            if hot and m - w >= 0 and terminator <= u:
                prevb = done.get(("bwd", j - w, max(u, terminator)))
                if prevb is None:
                    return None
                t = max(t, prevb)
            return t
        # backward
        if u == U - 1:
            prev = done.get(("fwd", j, U - 1))
            if prev is None:
                return None
            return prev + sim.head_fwd_s + sim.head_bwd_s
        nxt = done.get(("bwd", j, u + 1))
        if nxt is None:
            return None
        return nxt + hop(u)

    while remaining:
        # pick the schedulable op with the earliest (ready, dev_free) start;
        # prefer backward on ties (1F1B drains in-flight work first)
        best, best_start, best_ready = None, None, None
        for op in remaining:
            r = ready_time(op)
            if r is None:
                continue
            start = max(r, dev_free[op[2]])
            key = (start, 0 if op[0] == "bwd" else 1, op[1])
            if best is None or key < best_start:
                best, best_start, best_ready = op, key, r
        assert best is not None, "dependency deadlock"
        kind, j, u = best
        dur = stage_fwd(u) if kind == "fwd" else stage_bwd(u)
        start = max(best_ready, dev_free[u])
        end = start + dur
        dev_free[u] = end
        busy[u] += dur
        done[best] = end
        remaining.remove(best)

    total = max(dev_free)
    bubbles = total * U - sum(busy)

    # ---- memory model --------------------------------------------------------
    peak: Dict[int, float] = {}
    for u, (b, e) in enumerate(spans):
        w = sum(layers[i].weight_mb for i in range(b, e))
        ad = sum(layers[i].adapter_mb for i in range(b, e))
        hot_ad = sum(layers[i].adapter_mb for i in range(max(b, lowest_hot), e))
        opt = hot_ad * 3                     # fp32 moments + master
        mem = w + ad + opt + sim.embed_mb + sim.head_mb * 4
        if scheme == "pipe_adapter":
            # PipeDream-style: stash activations AND a weight version per
            # in-flight microbatch (up to U in flight)
            inflight = min(M, U)
            mem += inflight * sum(layers[i].act_mb for i in range(b, e))
            mem += (inflight - 1) * ad        # stale adapter copies
        elif ring_like:
            # staleness-free: one weight version; residuals only for hot blocks,
            # and only one microbatch's worth (strict 1F1B on hot devices)
            mem += sum(layers[i].act_mb for i in range(max(b, lowest_hot), e))
            if cached and u == terminator and lowest_hot > 0:
                # the boundary-activation ring buffer lives on the terminator:
                # one boundary tensor per microbatch per cached slot
                mem += cache_slots * M * layers[lowest_hot - 1].boundary_mb
            if packed and u == terminator and lowest_hot > 0:
                # conveyor queue: the frozen trunk races ahead of the hot
                # region, so up to (n_owners - 1) later owners' boundary
                # tensors wait at the terminator — packed trades memory for
                # fill/drain bubbles
                mem += ((n_owners - 1) * M
                        * layers[lowest_hot - 1].boundary_mb)
        peak[u] = mem

    return SimResult(total, peak, {u: busy[u] for u in range(U)}, bubbles)


# ---------------------------------------------------------------------------
# SPMD tick predictions for arbitrary (uneven) span layouts
# ---------------------------------------------------------------------------


def spmd_tick_round(spans, n_micro: int, boundary: int, *,
                    packed: bool = False, cached: bool = False,
                    n_owners: Optional[int] = None) -> Dict[str, int]:
    """Discrete-event prediction of the SPMD executor's Phase-A round ticks
    for an arbitrary (possibly uneven) span layout — the simulator half of
    the simulator-vs-executor differential harness.

    Under SPMD every stage's tick applies ``max_span`` padded block slots in
    lockstep, so a stage costs ONE tick per microbatch regardless of its span
    size.  The engine reproduces that by giving each frozen block unit cost
    and each device ``compute_speed == |its span|`` (stage time = span/span =
    exactly 1.0 — no float dust), with hot blocks, backwards, the head and
    links free: the engine's makespan over ``n_owners`` initiator-iterations
    IS the Phase-A tick count the executor's traced scans must realize
    (``pipeline_tick_counts(..., spans=...)``'s ``phase_a_round_ticks``:
    ``S*(M+F-1)`` scanned, ``S*M+F-1`` packed, 0 cached).

    Defined for boundaries with a terminator (``F < S``): RingAda always
    keeps at least the top block hot (depth >= 1), so the all-frozen
    degenerate round never executes.
    """
    spans = normalize_spans(spans)
    R, U = spans[-1][1], len(spans)
    F = frozen_stage_count(spans, boundary)
    n_owners = U if n_owners is None else n_owners
    layers = [LayerProfile(fwd_s=1.0 if i < boundary else 0.0, bwd_s=0.0,
                           act_mb=0.0, weight_mb=0.0, adapter_mb=0.0,
                           boundary_mb=0.0) for i in range(R)]
    devices = [DeviceProfile(compute_speed=float(sz), memory_mb=float("inf"))
               for sz in span_sizes(spans)]
    scheme = ("ringada_cached" if cached
              else "ringada_packed" if packed else "ringada")
    res = simulate_round(scheme, SimConfig(n_layers=R, n_devices=U,
                                           n_microbatches=n_micro),
                         layers, devices, unfreeze_depth=R - boundary,
                         spans=list(spans), n_owners=n_owners)
    ticks = int(round(res.time_per_round_s))
    assert abs(res.time_per_round_s - ticks) < 1e-9, res.time_per_round_s
    return {"phase_a_round_ticks": ticks, "frozen_stages": F,
            "hot_stages": U - F}


def full_round_ticks(spans, n_micro: int, boundary: int, *,
                     packed: bool = False, cached: bool = False,
                     n_owners: Optional[int] = None) -> Dict[str, int]:
    """Whole-round SPMD tick total: Phase A (via :func:`spmd_tick_round`)
    plus Phase B's per-owner hot fwd+bwd fill/drain, ``n_owners * 2 *
    (M + S_hot - 1)`` — the quantity the elastic bench gates recovery
    rounds on (a recovery/capture round re-pays Phase A; a steady cached
    round skips it entirely)."""
    n_owners = len(normalize_spans(spans)) if n_owners is None else n_owners
    t = spmd_tick_round(spans, n_micro, boundary, packed=packed,
                        cached=cached, n_owners=n_owners)
    hot = t["hot_stages"]
    t["phase_b_round_ticks"] = n_owners * 2 * (n_micro + hot - 1)
    t["round_ticks"] = t["phase_a_round_ticks"] + t["phase_b_round_ticks"]
    return t


def predict_recovery(n_blocks: int, survivors: Sequence[DeviceProfile],
                     n_micro: int, boundary: int, *, packed: bool = True,
                     spans=None, slots_per_epoch: int = 1) -> Dict[str, object]:
    """Closed-form/simulated cost of a checkpoint-free shrink recovery.

    Given the surviving fleet, predict the post-shrink layout
    (``spans_from_profiles`` unless explicit ``spans`` are given), the
    down-aligned unfreeze boundary, and the tick prices of (a) the recovery
    round — a full capture round at the new geometry (the cache was
    rebound, so Phase A runs end to end and re-captures) — and (b) the
    steady cached round that follows once the cache refills.  Mirrors
    exactly what ``RingExecutor.shrink`` + the next ``round()`` do, so the
    executor's measured recovery ledger must equal ``recovery`` here.
    """
    new_spans = (normalize_spans(spans, n_blocks) if spans is not None
                 else spans_from_profiles(n_blocks, survivors))
    b = align_boundary(new_spans, boundary)
    S_new = len(new_spans)
    # a capture/recovery round never packs a cached skip: F == S is excluded
    # upstream (depth >= 1), and packing needs F >= 2 to save anything
    F = frozen_stage_count(new_spans, b)
    eff_packed = packed and F >= 2
    recovery = full_round_ticks(new_spans, n_micro, b, packed=eff_packed,
                                n_owners=S_new)
    steady = full_round_ticks(new_spans, n_micro, b, cached=True,
                              n_owners=S_new)
    return {"spans": new_spans, "boundary": b,
            "frozen_stages": recovery["frozen_stages"],
            "hot_stages": recovery["hot_stages"],
            "recovery_round_ticks": recovery["round_ticks"],
            "recovery_phase_a_ticks": recovery["phase_a_round_ticks"],
            "steady_round_ticks": steady["round_ticks"],
            # every slot must re-capture once before all-hit rounds resume
            "rounds_to_cache_refill": slots_per_epoch,
            }


# ---------------------------------------------------------------------------
# Multi-round convergence-style run (paper Fig. 3(b) / Table I)
# ---------------------------------------------------------------------------


def simulate_training(scheme: str, sim: SimConfig,
                      layers: Sequence[LayerProfile],
                      devices: Sequence[DeviceProfile], *,
                      rounds: int, unfreeze_interval: int = 40,
                      initial_depth: int = 1,
                      spans: Optional[List[Tuple[int, int]]] = None,
                      slots_per_epoch: int = 1,
                      churn: Sequence[ChurnEvent] = (),
                      ) -> Tuple[float, float, List[float]]:
    """Returns (total_time_s, peak_memory_mb, cumulative_time_per_round).

    For ``scheme='ringada_cached'`` the first ``slots_per_epoch`` rounds after
    every boundary drop are capture rounds (full Phase A, simulated as plain
    ``ringada``); subsequent rounds at that boundary hit the cache.

    ``churn`` replays :class:`ChurnEvent`\\ s: each event fires BEFORE its
    round (``round=3`` means rounds 0-2 run on the old fleet).  A membership
    or speed change re-runs the speed-weighted assignment over the new fleet
    (explicit ``spans`` only survive until the first event — after churn
    they no longer cover the right device count) and resets the cached
    scheme's capture counter, so the ``slots_per_epoch`` rounds after a
    shrink are priced as full capture rounds — the simulated twin of the
    executor's checkpoint-free cache re-capture.
    """
    for ev in churn:
        if not isinstance(ev, ChurnEvent):
            raise TypeError(f"churn entries must be ChurnEvent, got {ev!r}")
    pending = sorted(churn, key=lambda ev: ev.round)
    fleet = list(devices)
    total, peak, times = 0.0, 0.0, []
    rounds_at_depth, last_depth = 0, None
    for r in range(rounds):
        while pending and pending[0].round <= r:
            ev = pending.pop(0)
            fleet = apply_churn(fleet, ev)
            if len(fleet) != sim.n_devices:
                sim = dataclasses.replace(sim, n_devices=len(fleet))
            spans = [list(sp) for sp in
                     spans_from_profiles(sim.n_layers, fleet)]
            rounds_at_depth, last_depth = 0, None   # recovery: re-capture
        depth = min(initial_depth + r // unfreeze_interval, sim.n_layers)
        rounds_at_depth = rounds_at_depth + 1 if depth == last_depth else 0
        last_depth = depth
        eff = scheme
        if scheme == "ringada_cached" and rounds_at_depth < slots_per_epoch:
            eff = "ringada"                       # first epoch: capture rounds
        res = simulate_round(eff, sim, layers, fleet,
                             unfreeze_depth=depth, spans=spans,
                             cache_slots=slots_per_epoch)
        total += res.time_per_round_s
        peak = max(peak, res.max_memory_mb)
        times.append(total)
    return total, peak, times
