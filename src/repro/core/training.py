"""Training and serving steps with RingAda's truncated backpropagation.

``split_trainable`` / ``merge_trainable`` realize the paper's trainable set: the
head plus every adapter above the unfreeze boundary. Gradients are taken *only*
with respect to that set, so XLA emits

  * no backward at all for the frozen trunk (stop_gradient scan split), and
  * no weight-gradient einsums for frozen backbone matrices in the hot region

— the two compute savings RingAda's early-stopped backpropagation provides.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import transformer as tfm
from repro.models.losses import cross_entropy, qa_span_loss
from repro.optim import adamw

Array = jax.Array


# ---------------------------------------------------------------------------
# Trainable split / merge
# ---------------------------------------------------------------------------


def split_trainable(params: Dict[str, Any], boundary: int) -> Dict[str, Any]:
    """Extract the differentiated leaves: hot adapter rows [b:] + head."""
    return {
        "adapters": tuple(jax.tree.map(lambda x: x[boundary:], e["adapter"])
                          for e in params["blocks"]),
        "head": params["head"],
    }


def full_trainable(params: Dict[str, Any]) -> Dict[str, Any]:
    """boundary=0 view — used to size optimizer state once."""
    return split_trainable(params, 0)


def merge_trainable(params: Dict[str, Any], trainable: Dict[str, Any],
                    boundary: int) -> Dict[str, Any]:
    """Rebuild the full param tree with hot adapter rows taken from ``trainable``."""
    blocks = []
    for e, hot in zip(params["blocks"], trainable["adapters"]):
        frozen = jax.tree.map(lambda x: lax.stop_gradient(x[:boundary]),
                              e["adapter"])
        ad = jax.tree.map(lambda f, h: jnp.concatenate([f, h], axis=0),
                          frozen, hot)
        blocks.append({**e, "adapter": ad})
    return {**params, "blocks": tuple(blocks), "head": trainable["head"]}


def write_back(params: Dict[str, Any], new_trainable_full: Dict[str, Any],
               ) -> Dict[str, Any]:
    """Install a full-size trainable tree (adapters [R,...] + head) into params."""
    blocks = tuple({**e, "adapter": ad}
                   for e, ad in zip(params["blocks"],
                                    new_trainable_full["adapters"]))
    return {**params, "blocks": blocks, "head": new_trainable_full["head"]}


def slice_to_full(params: Dict[str, Any], trainable_sliced: Dict[str, Any],
                  boundary: int) -> Dict[str, Any]:
    """Merge sliced hot rows with the existing frozen rows -> full-size tree."""
    ads = []
    for e, hot in zip(params["blocks"], trainable_sliced["adapters"]):
        ads.append(jax.tree.map(
            lambda x, h: jnp.concatenate([x[:boundary], h], axis=0),
            e["adapter"], hot))
    return {"adapters": tuple(ads), "head": trainable_sliced["head"]}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tc: TrainConfig, boundary: int, *,
                    impl: str = "jnp", with_memory: bool = False,
                    remat: bool = False, act_spec=None, moe_groups: int = 1):
    """Build a (jit-able) train step for a *static* unfreeze boundary.

    batch: {"tokens": [B,S] i32, "labels": [B,S] i32, optional "mask" [B,S],
            optional "memory": [B,T,D]}
    """

    def train_step(params, opt_state, batch):
        trainable = split_trainable(params, boundary)

        def loss_fn(tr):
            logits, aux = tfm.forward(params, batch["tokens"], cfg,
                                      memory=batch.get("memory"),
                                      boundary=boundary, impl=impl,
                                      remat=remat, act_spec=act_spec,
                                      moe_groups=moe_groups,
                                      hot_adapters=tr["adapters"],
                                      head_params=tr["head"])
            ce_chunk = 512 if cfg.out_dim >= 32768 else None
            loss, metrics = cross_entropy(logits, batch["labels"],
                                          batch.get("mask"), chunk=ce_chunk)
            metrics = {**metrics,
                       **{k: lax.stop_gradient(v) for k, v in aux.items()}}
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable)
        tr_full = slice_to_full(params, trainable, boundary)
        new_tr_full, new_opt = adamw.update(grads, opt_state, tr_full, tc,
                                            boundary)
        new_params = write_back(params, new_tr_full)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {**metrics, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_qa_train_step(cfg: ModelConfig, tc: TrainConfig, boundary: int, *,
                       impl: str = "jnp"):
    """SQuAD-style span-extraction step (the paper's task): batch carries
    {"tokens" [B,S], "starts" [B], "ends" [B]}; the head emits [B,S,2]."""
    assert cfg.head_out == 2, "qa step needs a span head (head_out=2)"

    def train_step(params, opt_state, batch):
        trainable = split_trainable(params, boundary)

        def loss_fn(tr):
            logits, _ = tfm.forward(params, batch["tokens"], cfg,
                                    boundary=boundary, impl=impl,
                                    hot_adapters=tr["adapters"],
                                    head_params=tr["head"])
            return qa_span_loss(logits, batch["starts"], batch["ends"])

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable)
        tr_full = slice_to_full(params, trainable, boundary)
        new_tr_full, new_opt = adamw.update(grads, opt_state, tr_full, tc,
                                            boundary)
        new_params = write_back(params, new_tr_full)
        return new_params, new_opt, metrics

    return train_step


def make_step(cfg: ModelConfig, tc: TrainConfig, boundary: int, *,
              impl: str = "jnp"):
    """Task-dispatching step builder: QA span head vs LM objective.

    The single entry point the session API (``repro.api``) and the launch
    driver share, so "which step fn does this config train with" is decided in
    exactly one place.
    """
    if cfg.head_out == 2:
        return make_qa_train_step(cfg, tc, boundary, impl=impl)
    return make_train_step(cfg, tc, boundary, impl=impl)


def make_eval_step(cfg: ModelConfig, *, impl: str = "jnp"):
    def eval_step(params, batch):
        logits, _ = tfm.forward(params, batch["tokens"], cfg,
                                memory=batch.get("memory"), impl=impl)
        loss, metrics = cross_entropy(logits, batch["labels"],
                                      batch.get("mask"))
        return metrics

    return eval_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, seq_len: int, *, impl: str = "jnp",
                      act_spec=None, moe_groups: int = 1):
    def prefill_step(params, tokens, memory=None):
        return tfm.prefill(params, tokens, cfg, memory=memory,
                           seq_len=seq_len, impl=impl, act_spec=act_spec,
                           moe_groups=moe_groups)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, impl: str = "jnp", greedy: bool = True,
                    act_spec=None):
    """One-token decode: (params, cache, token) -> (next_token, logits, cache)."""

    def serve_step(params, token, cache):
        logits, new_cache = tfm.decode_step(params, token, cache, cfg, impl=impl,
                                            act_spec=act_spec)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, new_cache

    return serve_step
