"""Scheduled top-down adapter unfreezing (RingAda Algorithm 1, coordinator side).

The schedule starts with only the head + the top-most adapter trainable
(``d = initial_unfreeze_depth``) and unfreezes one more adapter every
``unfreeze_interval`` steps (the paper uses k = 40):

    if r mod k == 0:  d <- d + 1

``depth`` counts *unfrozen* blocks from the top; the static scan-split
``boundary`` used by the model is ``boundary = R - depth_in_repeats`` (frozen
repeats from the bottom). Because the boundary is a static jit argument, every
depth change triggers one (cached) recompile — amortized over >= k steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig, TrainConfig


@dataclass(frozen=True)
class UnfreezeSchedule:
    initial_depth: int = 1
    interval: int = 40               # k
    max_depth: Optional[int] = None  # defaults to all blocks

    @staticmethod
    def from_train_config(tc: TrainConfig) -> "UnfreezeSchedule":
        return UnfreezeSchedule(initial_depth=tc.initial_unfreeze_depth,
                                interval=tc.unfreeze_interval,
                                max_depth=tc.max_unfreeze_depth)

    def depth_at(self, step: int, n_blocks: int) -> int:
        cap = min(self.max_depth or n_blocks, n_blocks)
        return min(self.initial_depth + step // self.interval, cap)


def depth_to_boundary(cfg: ModelConfig, depth: int) -> int:
    """Unfrozen-from-top depth (in *blocks*) -> frozen repeats from the bottom.

    Depth is rounded up to whole pattern repeats (a "superblock" for patterned
    archs like the VLM's [dense x4, cross x1]; a single layer for uniform archs).
    """
    per_rep = cfg.layers_per_repeat
    depth_reps = min(-(-depth // per_rep), cfg.repeats)
    return cfg.repeats - depth_reps


def boundary_schedule(cfg: ModelConfig, sched: UnfreezeSchedule, total_steps: int,
                      ) -> List[Tuple[int, int, int]]:
    """[(start_step, end_step, boundary)] segments with constant boundary.

    Driving the training loop off these segments gives exactly one jit cache
    entry per distinct boundary (the paper's runtime graph surgery, realized as
    staged recompilation).
    """
    n_blocks = cfg.n_layers
    segs: List[Tuple[int, int, int]] = []
    start = 0
    cur = depth_to_boundary(cfg, sched.depth_at(0, n_blocks))
    for s in range(1, total_steps):
        b = depth_to_boundary(cfg, sched.depth_at(s, n_blocks))
        if b != cur:
            segs.append((start, s, cur))
            start, cur = s, b
    segs.append((start, total_steps, cur))
    return segs
