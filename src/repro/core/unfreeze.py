"""Scheduled top-down adapter unfreezing (RingAda Algorithm 1, coordinator side).

The schedule starts with only the head + the top-most adapter trainable
(``d = initial_unfreeze_depth``) and unfreezes one more adapter every
``unfreeze_interval`` steps (the paper uses k = 40):

    if r mod k == 0:  d <- d + 1

``depth`` counts *unfrozen* blocks from the top; the static scan-split
``boundary`` used by the model is ``boundary = R - depth_in_repeats`` (frozen
repeats from the bottom). Because the boundary is a static jit argument, every
depth change triggers one (cached) recompile — amortized over >= k steps.

Schedules are **monotone top-down by contract**: depth never shrinks, so the
boundary never increases.  This is not just the paper's Algorithm 1 — the
frozen-trunk activation cache (``core/actcache.py``) keys entries by
``(batch_slot, boundary)`` and invalidates everything on a boundary *drop*;
a boundary that could come back up would silently serve stale activations.
Construction rejects non-monotone ``depths`` with a clear error, and the
executor re-checks at runtime.

``UnfreezeSchedule`` is the canonical "ScheduleLike": anything exposing
``depth_at(step, n_blocks) -> int`` can drive the drivers (``core/ring.py``,
``core/executor.py`` take a ``schedule=`` override) — ``repro.api.policies``
builds its pluggable ``UnfreezePolicy`` implementations on exactly that
surface, and ``repro.api.session.RingSession`` re-checks the monotone
contract per step for every one of them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig, TrainConfig


@dataclass(frozen=True)
class UnfreezeSchedule:
    initial_depth: int = 1
    interval: int = 40               # k
    max_depth: Optional[int] = None  # defaults to all blocks
    # Explicit per-segment depths (segment i covers steps [i*k, (i+1)*k), the
    # last entry holds forever).  Overrides the +1-per-interval rule; must be
    # non-decreasing (monotone top-down unfreezing).
    depths: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(
                f"unfreeze_interval must be >= 1, got {self.interval}")
        if self.initial_depth < 1:
            raise ValueError(
                f"initial_unfreeze_depth must be >= 1, got {self.initial_depth}")
        if self.depths is not None:
            if len(self.depths) == 0 or any(d < 1 for d in self.depths):
                raise ValueError(f"explicit depths must be >= 1: {self.depths}")
            drops = [(a, b) for a, b in zip(self.depths, self.depths[1:])
                     if b < a]
            if drops:
                raise ValueError(
                    f"non-monotone unfreeze schedule {self.depths}: depth "
                    f"shrinks at {drops} — RingAda unfreezes top-down only "
                    f"(the boundary may never increase; the activation "
                    f"cache's invalidation contract depends on it)")

    @staticmethod
    def from_train_config(tc: TrainConfig) -> "UnfreezeSchedule":
        return UnfreezeSchedule(initial_depth=tc.initial_unfreeze_depth,
                                interval=tc.unfreeze_interval,
                                max_depth=tc.max_unfreeze_depth)

    def depth_at(self, step: int, n_blocks: int) -> int:
        cap = min(self.max_depth or n_blocks, n_blocks)
        if self.depths is not None:
            seg = min(step // self.interval, len(self.depths) - 1)
            return min(self.depths[seg], cap)
        return min(self.initial_depth + step // self.interval, cap)


def depth_to_boundary(cfg: ModelConfig, depth: int) -> int:
    """Unfrozen-from-top depth (in *blocks*) -> frozen repeats from the bottom.

    Depth is rounded up to whole pattern repeats (a "superblock" for patterned
    archs like the VLM's [dense x4, cross x1]; a single layer for uniform archs).
    """
    per_rep = cfg.layers_per_repeat
    depth_reps = min(-(-depth // per_rep), cfg.repeats)
    return cfg.repeats - depth_reps


def boundary_schedule(cfg: ModelConfig, sched: UnfreezeSchedule, total_steps: int,
                      ) -> List[Tuple[int, int, int]]:
    """[(start_step, end_step, boundary)] segments with constant boundary.

    Driving the training loop off these segments gives exactly one jit cache
    entry per distinct boundary (the paper's runtime graph surgery, realized as
    staged recompilation).
    """
    n_blocks = cfg.n_layers
    segs: List[Tuple[int, int, int]] = []
    start = 0
    cur = depth_to_boundary(cfg, sched.depth_at(0, n_blocks))
    for s in range(1, total_steps):
        b = depth_to_boundary(cfg, sched.depth_at(s, n_blocks))
        if b != cur:
            if b > cur:
                raise ValueError(
                    f"non-monotone unfreeze schedule: boundary rises "
                    f"{cur} -> {b} at step {s} (RingAda unfreezes top-down "
                    f"only; see UnfreezeSchedule)")
            segs.append((start, s, cur))
            start, cur = s, b
    segs.append((start, total_steps, cur))
    return segs
