"""Serial adapter module — the paper's parameter-efficient trainable unit.

RingAda eq. (1):    h  <-  h + sigma(h @ W_down) @ W_up

The adapter sits after each block's FFN ("add & layer norm") sublayer, exactly as in
the serial-adapter variant the paper adopts (one adapter per transformer block).
``W_up`` is zero-initialized, so an adapter that has never been unfrozen is an exact
identity — this is what lets RingAda "deactivate" bottom-layer adapters and early-stop
backpropagation at the lowest *unfrozen* adapter without changing the function the
frozen trunk computes.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _act(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu}[name]


def apply_adapter(p: Dict[str, jax.Array], h: jax.Array, *,
                  activation: str = "gelu", impl: str = "jnp") -> jax.Array:
    """Apply the serial adapter to ``h`` ([..., D])."""
    if impl == "pallas":
        from repro.kernels import ops

        return ops.adapter_fused(h, p["w_down"], p["w_up"], activation=activation)
    mid = _act(activation)(h.astype(jnp.float32) @ p["w_down"].astype(jnp.float32))
    out = mid @ p["w_up"].astype(jnp.float32)
    return h + out.astype(h.dtype)


def adapter_param_count(d_model: int, bottleneck: int) -> int:
    return 2 * d_model * bottleneck


def adapter_flops(tokens: int, d_model: int, bottleneck: int) -> int:
    """Forward FLOPs for one adapter over ``tokens`` tokens."""
    return 4 * tokens * d_model * bottleneck
