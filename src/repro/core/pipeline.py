"""RingAda ring pipeline on an SPMD ``stage`` mesh axis (shard_map + ppermute).

The paper's ring of edge devices maps to a mesh axis: stage ``s`` holds repeats
``[s*Lps, (s+1)*Lps)`` of the block stack (params stage-stacked and sharded), plus a
replicated copy of the embedding and head — exactly the paper's deployment.

One *training round* (Algorithm 1, initiator = ``owner``):

  1. The owner embeds its local microbatches and ships them to stage 0 (paper:
     initiator sends embeddings to the client holding the lowest Trm block).
  2. **Phase A — frozen trunk, forward-only streaming**: stages ``[0, F)`` hold only
     frozen adapters (``F = boundary / Lps``). Their tick-pipeline runs entirely
     under ``stop_gradient``: ``M + F - 1`` ticks, never any backward — the paper's
     "clients with all-frozen adapters continuously forward consecutive batches".
  3. **Phase B — hot region, strict 1F1B**: stages ``[F, S)`` run a differentiable
     tick-pipeline (``M + S_hot - 1`` ticks). ``jax.grad`` through the tick scan +
     ``ppermute`` yields the reverse-tick backward pipeline automatically (cotangents
     ppermute backwards along the ring), early-stopping at stage F — the paper's
     *terminator*.
  4. The last stage's outputs return to the owner; the owner computes the loss
     against its local labels (labels never leave their device), the head gradient
     is ``psum``-shared, and adapter gradients stay local to their stage — no
     weight-gradient traffic, matching the paper's communication pattern.

This module provides the ring *round* in two forms, split from the drivers that
consume them (the executor split):

  * ``make_ring_round`` / ``make_ring_train_round`` — the reference path: owner
    is **static**, the owner->stage0 and last->owner hops are static ``ppermute``
    tables, and each (owner, boundary) pair is its own executable.  Driven by
    ``core/ring.py``'s ``RingTrainer`` (S executables per boundary, host-side
    optimizer).
  * ``ring_round_local`` — the fused path: owner is a **traced** scalar, the two
    owner-dependent hops become ``all_gather`` + dynamic-index rotations (a
    dynamic permute), so one executable serves every owner.
    ``core/executor.py``'s ``RingExecutor`` scans this over all S owners and
    runs the stage-masked optimizer *inside* a single donated jit.
    ``ring_round_local`` is itself the composition of two halves,
    ``ring_phase_a`` (embeddings -> stage-``F`` boundary activations) and
    ``ring_phase_b`` (boundary activations -> local loss), exposed separately
    so the executor can cache the Phase-A output.

Packed-conveyor Phase A (``ring_phase_a_packed``):

  The fused executor's owner scan re-enters Phase A once per owner — S
  independent ``M + F - 1``-tick pipelines per round, each paying its own
  ``F - 1``-tick fill/drain bubble.  Because the frozen trunk is constant for
  the whole round, all S owners' streams can instead be packed back-to-back
  into ONE ``S*M + F - 1``-tick conveyor run before the owner scan, which
  then consumes the resulting ``[S, M, ...]`` boundary stack by dynamic
  index.  Saves ``(S-1)*(F-1)`` ticks per round on every direct/capture
  round; capture writes all S owners' boundary activations in one pass.

Phase-A skip (the frozen-trunk activation cache, ``core/actcache.py``):

  Everything Phase A reads — the embedding table, the frozen trunk's backbone
  weights, and the frozen stages' adapters — is *outside* RingAda's trainable
  set while the boundary holds (the optimizer's stage mask keeps frozen
  adapters and their moments bit-identical).  Its output, the stage-``F``
  input activations ``h_B``, is therefore bit-identical across rounds for the
  same microbatches at the same boundary.  ``RingExecutor`` exploits this:
  the first time a batch slot is seen at a boundary it runs a *capture*
  executable (full round, Phase-A outputs written to a donated device ring
  buffer), and on every later visit a *cached* executable enters the pipeline
  directly at stage ``F`` — no embed, no ``all_gather``, none of the
  ``M + F - 1`` frozen-trunk ticks per owner-iteration.

  Invalidation rules: entries are keyed ``(batch_slot, boundary)``.  The
  unfreeze schedule is monotone top-down (``core/unfreeze.py`` rejects
  anything else), so when the boundary drops every cached entry is
  permanently unreachable and the whole cache is dropped in one invalidation.
  Within a boundary segment nothing the cache depends on can change, so no
  finer-grained invalidation exists.  Disable the cache (capacity 0 / no
  batch slots) for streaming or non-repeating data — a slot that is never
  revisited only pays the capture write without ever hitting.

Heterogeneous (ragged) span layouts:

  The paper's coordinator assigns *uneven* contiguous block spans to
  heterogeneous devices (Algorithm 1's 4:5:2:3 example).  Every builder here
  takes ``spans=`` ([(begin, end)] per stage, ``partition.assign_layers``
  output plugs in directly): stage stacks are padded to ``max_span`` with a
  per-stage validity mask (padding rows are clamped duplicates whose
  applications are masked out of the residual stream), so the tick pipeline
  stays ONE traced ``lax.scan`` under SPMD — each stage ticks in lockstep
  applying exactly its own span.  Boundaries must fall on span edges
  (``partition.align_boundary`` rounds down).  Uniform layouts
  (``spans=None``) keep the historical unmasked fast path bit-for-bit.

SPMD adaptation (DESIGN.md §6): per-device *program* asymmetry is impossible under
SPMD, so the paper's per-device savings appear as globally shorter backward tick
scans and absent residual stashes for phase A, uniform across devices. The
discrete-event simulator (core/simulator.py) models the true MPMD overlap
(``scheme='ringada_cached'`` models the cached steady state;
``spmd_tick_round`` predicts the executor's tick ledger for any span layout).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.partition import (Span, frozen_stage_count, normalize_spans,
                                  span_sizes, uniform_assignment)
from repro.models import transformer as tfm
from repro.models.blocks import BlockCtx, apply_block

Array = jax.Array


# ---------------------------------------------------------------------------
# Stage-stacked parameters (uniform OR ragged span layouts)
# ---------------------------------------------------------------------------


def resolve_spans(n_blocks: int, n_stages: int,
                  spans: Optional[Sequence[Span]] = None) -> Tuple[Span, ...]:
    """Canonical span layout: the given one (validated against the model) or
    the balanced default.  ``assign_layers`` output plugs in directly."""
    if spans is None:
        spans = uniform_assignment(n_blocks, n_stages)
    spans = normalize_spans(spans, n_blocks)
    if len(spans) != n_stages:
        raise ValueError(
            f"span layout {list(spans)} has {len(spans)} stages, mesh has "
            f"{n_stages}")
    return spans


def span_maps(spans: Sequence[Span]) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray, np.ndarray]:
    """Static index maps between the flat [R, ...] block stack and the padded
    [S, max_span, ...] stage stack:

      stack_idx [S, max_span] — global block index feeding stage row (u, j);
        padding rows clamp to the stage's last real block (real weights, so
        masked-out applications can never produce NaNs),
      valid     [S, max_span] — True where row (u, j) holds a real block,
      stage_of  [R]           — owning stage of global block r,
      slot_of   [R]           — row of block r inside its stage's stack.
    """
    sizes = span_sizes(spans)
    S, mx = len(spans), max(sizes)
    R = spans[-1][1]
    stack_idx = np.zeros((S, mx), np.int32)
    valid = np.zeros((S, mx), bool)
    stage_of = np.zeros(R, np.int32)
    slot_of = np.zeros(R, np.int32)
    for u, (b, e) in enumerate(spans):
        n = e - b
        stack_idx[u, :n] = np.arange(b, e)
        stack_idx[u, n:] = e - 1
        valid[u, :n] = True
        stage_of[b:e] = u
        slot_of[b:e] = np.arange(n)
    return stack_idx, valid, stage_of, slot_of


def is_ragged(spans: Sequence[Span]) -> bool:
    return len(set(span_sizes(spans))) > 1


def stack_entry(entry: Any, spans: Sequence[Span], *, leading: int = 0) -> Any:
    """Flat block-entry tree (leaves [R, C, ...]) -> padded stage stack
    (leaves [S, max_span, C, ...]).  Uniform layouts keep the original
    zero-copy reshape; ragged layouts gather through ``span_maps`` (padding
    rows duplicate the stage's last block and are masked in the forward).

    ``leading`` extra axes before the block axis pass through untouched —
    the multi-tenant executor stacks tenant-major ``[T, R, C, ...]`` adapter
    trees with ``leading=1`` (-> ``[T, S, max_span, C, ...]``)."""
    S = len(spans)
    lead = (slice(None),) * leading
    if not is_ragged(spans):
        lps = span_sizes(spans)[0]
        return jax.tree.map(
            lambda x: x.reshape(x.shape[:leading] + (S, lps)
                                + x.shape[leading + 1:]), entry)
    stack_idx, _, _, _ = span_maps(spans)
    idx = jnp.asarray(stack_idx)
    return jax.tree.map(lambda x: x[lead + (idx,)], entry)


def unstack_entry(stacked: Any, spans: Sequence[Span], *,
                  leading: int = 0) -> Any:
    """Inverse of :func:`stack_entry`: padded [S, max_span, C, ...] leaves ->
    flat [R, C, ...] leaves (padding rows dropped).  ``leading`` as in
    :func:`stack_entry`."""
    R = spans[-1][1]
    lead = (slice(None),) * leading
    if not is_ragged(spans):
        return jax.tree.map(
            lambda x: x.reshape(x.shape[:leading] + (R,)
                                + x.shape[leading + 2:]), stacked)
    _, _, stage_of, slot_of = span_maps(spans)
    u, j = jnp.asarray(stage_of), jnp.asarray(slot_of)
    return jax.tree.map(lambda x: x[lead + (u, j)], stacked)


def stage_stack(params: Dict[str, Any], cfg: ModelConfig, n_stages: int, *,
                spans: Optional[Sequence[Span]] = None
                ) -> Tuple[Any, Dict[str, Any]]:
    """Split params into (stage_blocks, shared).

    stage_blocks: block-stack leaves stacked [S, max_span, C, ...] (shard on
    'stage'): stage ``u`` holds blocks ``spans[u]``, rows past its span are
    clamped duplicates masked out of the forward.  ``spans=None`` is the
    balanced split (the classic [S, R/S, C, ...] when R divides evenly).
    shared: embed / final_norm / head (+meta), replicated on every stage — the
    paper keeps Emb + Hed copies on every client.
    """
    assert len(cfg.pattern) == 1, "ring pipeline requires a uniform layer pattern"
    spans = resolve_spans(cfg.repeats, n_stages, spans)
    stage_blocks = stack_entry(params["blocks"][0], spans)
    shared = {k: v for k, v in params.items() if k != "blocks"}
    return stage_blocks, shared


def unstack(stage_blocks, cfg: ModelConfig, params: Dict[str, Any],
            shared: Dict[str, Any], *,
            spans: Optional[Sequence[Span]] = None) -> Dict[str, Any]:
    """Inverse of stage_stack: rebuild the flat [R, C, ...] param tree."""
    n_stages = len(jax.tree.leaves(stage_blocks)[0])
    spans = resolve_spans(cfg.repeats, n_stages, spans)
    entry = unstack_entry(stage_blocks, spans)
    return {**params, **shared, "blocks": (entry,)}


# ---------------------------------------------------------------------------
# Per-stage layer application
# ---------------------------------------------------------------------------


def _apply_stage_layers(cfg: ModelConfig, stage_params, h: Array,
                        positions: Array, valid: Optional[Array] = None
                        ) -> Array:
    """Apply this stage's local blocks (leaves [max_span, C, ...]) to h
    [mb, seq, D].  ``valid`` ([max_span] bool, stage-local) masks padding
    rows of a ragged span layout: an invalid row's application is discarded
    (the residual stream passes through unchanged), so every stage scans the
    same ``max_span`` slots under SPMD while computing exactly its own span.
    ``valid=None`` (uniform layouts) keeps the unmasked fast path."""
    ctx = BlockCtx(cfg=cfg, mode="seq", positions=positions, causal=True,
                   q_chunk=tfm.pick_chunk(h.shape[1]))
    kind = cfg.pattern[0][0]

    def body(carry, xs):
        p_slice = xs if valid is None else xs[0]

        def inner(c2, p2):
            h3, _, _ = apply_block(kind, cfg, p2, c2, ctx, None)
            return h3, None

        h2, _ = lax.scan(inner, carry, p_slice)
        if valid is not None:
            h2 = jnp.where(xs[1], h2, carry)
        return h2, None

    xs = stage_params if valid is None else (stage_params, valid)
    h, _ = lax.scan(body, h, xs)
    return h


def _tick_phase(cfg: ModelConfig, s: Array, pos: Array, fwd_perm, n_micro: int,
                blocks_slice, h_inject: Array, first_stage, depth: int,
                valid: Optional[Array] = None, record=None) -> Array:
    """Tick pipeline over stages [first, first+depth); returns the
    [M, mb, seq, D] outputs emitted by stage first+depth-1 (stage-local:
    only meaningful on that stage).  ``record`` (if given) is called with the
    scan length at trace time — the executor's measured tick ledger."""
    M = n_micro
    T = M + depth - 1
    if record is not None:
        record(T)
    rel = s - first_stage

    def tick(carry, t):
        buf = carry
        inject = (rel == 0) & (t < M)
        incoming = jnp.where(inject, h_inject[jnp.minimum(t, M - 1)], buf)
        active = (rel >= 0) & (rel < depth) & (t - rel >= 0) & (t - rel < M)
        out = _apply_stage_layers(cfg, blocks_slice, incoming, pos, valid)
        out = jnp.where(active, out, incoming)
        nxt = lax.ppermute(out, "stage", fwd_perm)
        return nxt, out

    _, emits = lax.scan(tick, jnp.zeros_like(h_inject[0]), jnp.arange(T))
    take = jnp.arange(M) + depth - 1
    return emits[take]                                         # [M, mb, seq, D]


# ---------------------------------------------------------------------------
# One RingAda round as a shard_map'd, differentiable function (static owner)
# ---------------------------------------------------------------------------


def _stage_valid(spans, s) -> Optional[Array]:
    """Stage-local [max_span] validity row for ragged layouts (None when the
    layout is uniform — the unmasked fast path stays bit-identical)."""
    if not is_ragged(spans):
        return None
    _, valid, _, _ = span_maps(spans)
    return jnp.asarray(valid)[s]


def make_ring_round(cfg: ModelConfig, mesh: Mesh, *, n_stages: int, owner: int,
                    boundary: int, n_micro: int,
                    spans: Optional[Sequence[Span]] = None):
    """Build ``loss_fn(stage_blocks, shared, tokens, labels) -> loss``.

    Static per build: (owner, boundary, spans). boundary must be span-aligned
    (fall on a stage edge of ``spans``; stage-aligned in the uniform case).
    Global input shapes:
      stage_blocks leaves [S, max_span, C, ...] sharded P('stage')
      shared                                  replicated P()
      tokens / labels [S, M, mb, seq]         sharded P('stage')  (per-client data)
    """
    spans = resolve_spans(cfg.repeats, n_stages, spans)
    F = frozen_stage_count(spans, boundary)
    S_hot = n_stages - F
    M = n_micro
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def round_fn(stage_blocks, shared, tokens, labels):
        s = lax.axis_index("stage")
        my_blocks = jax.tree.map(lambda x: x[0], stage_blocks)  # [max_span,...]
        valid = _stage_valid(spans, s)
        my_tokens = tokens[0]                                     # [M, mb, seq]
        my_labels = labels[0]
        mb, seq = my_tokens.shape[1], my_tokens.shape[2]
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (mb, seq))

        # 1. owner embeds; one static hop owner -> stage 0
        emb_all = jax.vmap(lambda t: tfm.embed(cfg, shared, t, pos))(my_tokens)
        shift0 = [(i, (i - owner) % n_stages) for i in range(n_stages)]
        emb_at0 = lax.ppermute(emb_all, "stage", shift0)

        phase = lambda blocks_slice, h_inject, first, depth: _tick_phase(
            cfg, s, pos, fwd_perm, M, blocks_slice, h_inject, first, depth,
            valid)

        # 2. Phase A (forward-only streaming, no autodiff possible by construction)
        if F > 0:
            outs_A = phase(lax.stop_gradient(my_blocks),
                           lax.stop_gradient(emb_at0), 0, F)
            outs_A = lax.stop_gradient(outs_A)
            h_B = lax.ppermute(outs_A, "stage", fwd_perm)          # stage F-1 -> F
        else:
            h_B = emb_at0

        # 3. Phase B (hot 1F1B pipeline; grad => reverse ticks, stops at stage F)
        outs_B = phase(my_blocks, h_B, F, S_hot)

        # 4. back to the owner; loss on the owner's local labels
        shift_back = [(i, (i - (n_stages - 1) + owner) % n_stages)
                      for i in range(n_stages)]
        finals = lax.ppermute(outs_B, "stage", shift_back)
        logits = jax.vmap(lambda hh: tfm.head(cfg, shared, hh))(finals)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, my_labels[..., None], axis=-1)[..., 0]
        is_owner = (s == owner).astype(jnp.float32)
        loss = jnp.mean(lse - gold) * is_owner
        return lax.psum(loss, "stage")

    return compat.shard_map(round_fn, mesh=mesh,
                            in_specs=(P("stage"), P(), P("stage"), P("stage")),
                            out_specs=P())


def make_ring_train_round(cfg: ModelConfig, mesh: Mesh, *, n_stages: int,
                          owner: int, boundary: int, n_micro: int,
                          spans: Optional[Sequence[Span]] = None):
    """Returns fn(stage_blocks, shared, tokens, labels) ->
    (loss, (adapter_grads [S,max_span,C,...] stage-local, head_grads
    replicated))."""
    loss_fn = make_ring_round(cfg, mesh, n_stages=n_stages, owner=owner,
                              boundary=boundary, n_micro=n_micro, spans=spans)

    def train_round(stage_blocks, shared, tokens, labels):
        def wrapped(adapters, head_p):
            blocks2 = {**stage_blocks, "adapter": adapters}
            shared2 = {**shared, "head": head_p}
            return loss_fn(blocks2, shared2, tokens, labels)

        loss, grads = jax.value_and_grad(wrapped, argnums=(0, 1))(
            stage_blocks["adapter"], shared["head"])
        return loss, grads

    return train_round


# ---------------------------------------------------------------------------
# One RingAda round as a *local* function with a traced owner (fused path)
# ---------------------------------------------------------------------------


def gather_embeddings(cfg: ModelConfig, shared: Dict[str, Any],
                      my_tokens: Array, pos: Array) -> Array:
    """All stages' embedded microbatches, gathered once per round.

    The embedding table is outside RingAda's trainable set (adapters + head),
    so within a round the embeddings are round-constant: the fused executor
    hoists this single ``all_gather`` out of the owner scan instead of paying
    an owner->stage0 hop per iteration.  Returns [S, M, mb, seq, D]."""
    emb_all = jax.vmap(lambda t: tfm.embed(cfg, shared, t, pos))(my_tokens)
    return lax.all_gather(emb_all, "stage")


def _ring_geometry(cfg: ModelConfig, n_stages: int, boundary: int,
                   spans: Optional[Sequence[Span]] = None
                   ) -> Tuple[Tuple[Span, ...], int]:
    """(canonical spans, frozen-stage count F) for a span-aligned boundary."""
    spans = resolve_spans(cfg.repeats, n_stages, spans)
    return spans, frozen_stage_count(spans, boundary)


def ring_phase_a(cfg: ModelConfig, *, n_stages: int, boundary: int,
                 n_micro: int, spans: Optional[Sequence[Span]] = None,
                 record=None):
    """Phase A of the local round: embeddings -> stage-``F`` boundary inputs.

    Returns ``fn(owner, my_blocks, emb_g) -> h_B`` ([M, mb, seq, D]
    stage-local), where ``h_B`` is exactly what Phase B injects at stage F:
    the frozen trunk's outputs after the F-1 -> F hop (or, at boundary 0, the
    owner's embeddings dynamically rotated to stage 0).  Always emitted under
    ``stop_gradient`` — the trunk is frozen by construction, which is also
    what makes ``h_B`` cacheable across rounds (see module docstring).
    """
    S = n_stages
    spans, F = _ring_geometry(cfg, n_stages, boundary, spans)
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]

    def phase_a(owner, my_blocks, emb_g):
        s = lax.axis_index("stage")
        valid = _stage_valid(spans, s)
        seq = emb_g.shape[3]
        mb = emb_g.shape[2]
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (mb, seq))

        # owner -> stage 0: stage j reads stage (j+owner)'s embeddings
        emb_at0 = lax.dynamic_index_in_dim(emb_g, (s + owner) % S, 0,
                                           keepdims=False)
        if F > 0:
            outs_A = _tick_phase(cfg, s, pos, fwd_perm, n_micro,
                                 lax.stop_gradient(my_blocks),
                                 lax.stop_gradient(emb_at0), 0, F,
                                 valid, record)
            outs_A = lax.stop_gradient(outs_A)
            h_B = lax.ppermute(outs_A, "stage", fwd_perm)
        else:
            h_B = emb_at0
        return lax.stop_gradient(h_B)

    return phase_a


def ring_phase_a_packed(cfg: ModelConfig, *, n_stages: int, boundary: int,
                        n_micro: int, spans: Optional[Sequence[Span]] = None,
                        record=None, n_tenants: int = 1):
    """Packed-conveyor Phase A: ALL owners' boundary inputs in one pipeline.

    The per-owner ``ring_phase_a`` runs S independent ``M + F - 1``-tick
    pipelines per round (one inside each owner-iteration of the executor's
    scan), so each owner re-pays the ``F - 1``-tick fill/drain bubble.  But
    everything Phase A reads is frozen for the whole round — the stage-masked
    optimizer keeps frozen adapters bit-identical across owner-iterations —
    so nothing forces the streams apart: this builder concatenates all S
    owners' microbatches into one continuous ``S*M``-deep injection stream
    and runs a single ``S*M + F - 1``-tick conveyor, the paper's "clients
    with all-frozen adapters continuously forward consecutive batches" taken
    across initiators.  Per round that saves ``(S-1)*(F-1)`` ticks
    (``pipeline_tick_counts(packed=True)`` pins both formulas against the
    discrete-event simulator).

    Returns ``fn(my_blocks, emb_g) -> h_B_all`` ([S_owner, M, mb, seq, D]
    stage-local): owner ``o``'s slice is bit-for-bit what ``ring_phase_a``
    would have produced for that owner (same per-microbatch op sequence, only
    the conveyor length differs), emitted under ``stop_gradient``.  There is
    no ``owner`` argument — the executor indexes the stack inside its owner
    scan, and capture mode writes the whole stack to the cache in one pass.

    Multi-tenant (``n_tenants=T > 1``): ``emb_g`` carries a tenant axis —
    [S_owner, T, M, mb, seq, D] — and the pack axis extends from S owners to
    T·S tenant-owners: one continuous ``T*S*M + F - 1``-tick conveyor moves
    every tenant-owner microbatch of the round (slot ``t*S*M + o*M + m`` is
    tenant t / owner o / microbatch m — tenant-major, i.e. tenant 0's PR-4
    stream followed by tenant 1's, ...).  This is valid for the same reason
    the single-tenant pack is: the trunk is frozen for the whole round AND
    bit-identical across tenants (the stage-masked optimizer's frozen-region
    invariant extends across the tenant axis — every tenant's frozen adapter
    rows stay at their shared init), so nothing forces the T·S streams
    apart.  Per-tick shapes are EXACTLY the single-tenant conveyor's
    ([mb, seq, D] per stage), so each microbatch sees a bit-identical op
    sequence to its own single-tenant run — only the conveyor length
    differs; tests/test_tenants.py pins the joint-vs-independent oracle on
    this.  Per tenant the round pays ``S*M + (F-1)/T`` ticks instead of
    ``S*M + F - 1``: the fill/drain bubble is paid once across all T·S·M
    microbatches (the amortization ``benchmarks/pipeline_bench.py`` gates).
    Output: [S_owner, T, M, mb, seq, D].
    """
    S = n_stages
    spans, F = _ring_geometry(cfg, n_stages, boundary, spans)
    M = n_micro
    T = n_tenants
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]

    def phase_a_packed(my_blocks, emb_g):
        s = lax.axis_index("stage")
        valid = _stage_valid(spans, s)
        seq = emb_g.shape[-2]
        mb = emb_g.shape[-3]
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                               (mb, seq))

        # Owner-major injection stream: conveyor slot o*M + m carries owner
        # o's microbatch m (tenant-major ``t*S*M + o*M + m`` at T > 1).
        # ``emb_g`` is the all_gather'd (replicated) embedding stack and only
        # the rel-0 stage of the tick pipeline ever reads its injection
        # (``_tick_phase`` masks every other stage), so stage 0 reading
        # ``emb_g[o, m]`` is exactly ``ring_phase_a``'s owner -> stage-0
        # dynamic permute for every owner at once.
        if T == 1:
            inject = emb_g.reshape((S * M,) + emb_g.shape[2:])
        else:
            # [S, T, M, mb, seq, D] -> [T, S, M, ...] -> [T*S*M, mb, seq, D]
            e = jnp.swapaxes(emb_g, 0, 1)
            inject = e.reshape((T * S * M,) + e.shape[3:])
        if F > 0:
            outs = _tick_phase(cfg, s, pos, fwd_perm, T * S * M,
                               lax.stop_gradient(my_blocks),
                               lax.stop_gradient(inject), 0, F,
                               valid, record)
            outs = lax.stop_gradient(outs)
            h = lax.ppermute(outs, "stage", fwd_perm)      # stage F-1 -> F
        else:
            h = inject
        if T == 1:
            out = h.reshape((S, M) + emb_g.shape[2:])
        else:
            out = jnp.swapaxes(
                h.reshape((T, S, M) + emb_g.shape[3:]), 0, 1)
        return lax.stop_gradient(out)

    return phase_a_packed


def ring_phase_b(cfg: ModelConfig, *, n_stages: int, boundary: int,
                 n_micro: int, spans: Optional[Sequence[Span]] = None,
                 record=None):
    """Phase B of the local round: stage-``F`` inputs -> local masked loss.

    Returns ``fn(owner, my_blocks, shared, h_B, my_labels) -> local_loss``.
    This is the only differentiable half: the hot 1F1B tick pipeline over
    stages [F, S), the last-stage -> owner hop, and the owner-local loss.
    ``h_B`` may come from ``ring_phase_a`` live or from the activation cache —
    the cache stores exactly the bits the capturing executable computed, and
    nothing Phase A reads changes while the boundary holds (differently-fused
    executables may still differ in float ulps; tests pin allclose).
    """
    spans, F = _ring_geometry(cfg, n_stages, boundary, spans)
    S = n_stages
    S_hot = S - F
    M = n_micro
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]
    # stacked static tables: branch o ships stage S-1's outputs home to owner o
    back_tables = [[(i, (i - (S - 1) + o) % S) for i in range(S)]
                   for o in range(S)]

    def phase_b(owner, my_blocks, shared, h_B, my_labels):
        s = lax.axis_index("stage")
        valid = _stage_valid(spans, s)
        mb, seq = my_labels.shape[1], my_labels.shape[2]
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (mb, seq))

        # hot 1F1B pipeline; grad => reverse ticks, stops at stage F
        outs_B = _tick_phase(cfg, s, pos, fwd_perm, M, my_blocks, h_B, F,
                             S_hot, valid, record)

        # last stage -> owner: switch over the stacked static tables
        finals = lax.switch(
            owner,
            [lambda h, t=tbl: lax.ppermute(h, "stage", t) for tbl in back_tables],
            outs_B)
        logits = jax.vmap(lambda hh: tfm.head(cfg, shared, hh))(finals)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, my_labels[..., None], axis=-1)[..., 0]
        is_owner = (s == owner).astype(jnp.float32)
        return jnp.mean(lse - gold) * is_owner           # LOCAL (not psum'd)

    return phase_b


def ring_round_local(cfg: ModelConfig, *, n_stages: int, boundary: int,
                     n_micro: int, spans: Optional[Sequence[Span]] = None):
    """Local (per-shard) RingAda round with a **traced** owner.

    Returns ``fn(owner, my_blocks, shared, emb_g, my_labels) -> local_loss``
    meant to be called *inside* an existing shard_map over 'stage' (arguments
    already stage-local: my_blocks leaves [lps, C, ...]; ``emb_g`` is
    ``gather_embeddings``' [S, M, mb, seq, D] round-constant embedding stack).

    Owner enters as a traced i32 scalar, so ONE executable serves every owner
    and the executor can ``lax.scan`` over owners inside a single jit.  The
    owner-dependent static ppermute tables of ``make_ring_round`` become

      * owner -> stage 0: a dynamic index into the pre-gathered embeddings
        (stage j reads stage (j+owner)'s microbatches — a dynamic permute), and
      * last stage -> owner: ``lax.switch`` over the S precomputed static
        ppermute tables (all branches compile once; only the owner's executes).

    The returned loss is the **local** masked contribution (nonzero only on the
    owner stage), NOT psum'd: differentiate it directly — the collective
    transposes (ppermute inverse, scatter-sum) route cotangents across stages
    so the per-stage grads equal the reference path's.  psum the values (once
    per round) and the head grads (once per iteration) afterwards.

    Composition of ``ring_phase_a`` and ``ring_phase_b`` (the executor calls
    the halves directly so it can capture / reuse the Phase-A output).
    """
    phase_a = ring_phase_a(cfg, n_stages=n_stages, boundary=boundary,
                           n_micro=n_micro, spans=spans)
    phase_b = ring_phase_b(cfg, n_stages=n_stages, boundary=boundary,
                           n_micro=n_micro, spans=spans)

    def local_fn(owner, my_blocks, shared, emb_g, my_labels):
        h_B = phase_a(owner, my_blocks, emb_g)
        return phase_b(owner, my_blocks, shared, h_B, my_labels)

    return local_fn


def pipeline_tick_counts(n_stages: int, n_micro: int, boundary: int,
                         lps: Optional[int] = None, *, cached: bool = False,
                         packed: bool = False,
                         spans: Optional[Sequence[Span]] = None
                         ) -> Dict[str, int]:
    """Analytic tick counts (used by tests and the §Perf log).

    PipeAdapter (boundary 0): fwd M+S-1, bwd M+S-1.
    RingAda: fwd (M+F-1) + (M+S_hot-1) + 1 hop, bwd M+S_hot-1.
    RingAda + actcache steady state (``cached=True``): the whole Phase-A tick
    scan vanishes — fwd M+S_hot-1 only, bwd unchanged.
    RingAda + packed conveyor (``packed=True``, ``ring_phase_a_packed``):
    Phase A leaves the owner-iteration — all S owners' frozen-trunk streams
    run once per ROUND as one ``S*M + F - 1``-tick conveyor instead of S
    separate ``M + F - 1``-tick pipelines (``S*(M+F-1)`` ticks), saving
    ``(S-1)*(F-1)`` fill/drain bubble ticks per round.

    ``fwd_ticks``/``bwd_ticks`` are per owner-iteration (Phase A excluded
    when it is hoisted or skipped); ``phase_a_round_ticks`` is the whole
    round's Phase-A conveyor length and ``phase_a_saved_ticks`` the packed
    scheme's per-round saving — both pinned against the discrete-event
    simulator in tests/test_simulator.py.

    Pass either ``lps`` (uniform layouts: ``F = boundary // lps``) or
    ``spans`` (ragged layouts: ``F`` = frozen stages of the span-aligned
    boundary).  Tick counts are in STAGE ticks — under SPMD every stage's
    tick applies ``max_span`` block slots (padding masked), so the counts
    are layout-shape-independent given ``F``; tests/test_partition_exec.py
    pins them against the executor's measured scan lengths per layout.
    """
    if spans is not None:
        assert lps is None or lps * n_stages == normalize_spans(spans)[-1][1], \
            "pass lps or spans, not disagreeing both"
        F = frozen_stage_count(normalize_spans(spans), boundary)
    else:
        assert lps is not None, "pass lps (uniform) or spans (ragged)"
        F = boundary // lps
    S_hot = n_stages - F
    phase_a = 0 if (cached or packed or F == 0) else n_micro + F - 1
    if cached or F == 0:
        a_round = 0
    elif packed:
        a_round = n_stages * n_micro + F - 1
    else:
        a_round = n_stages * (n_micro + F - 1)
    saved = ((n_stages - 1) * (F - 1)
             if (packed and not cached and F > 0) else 0)
    return {
        "fwd_ticks": phase_a + n_micro + S_hot - 1,
        "bwd_ticks": n_micro + S_hot - 1,
        "frozen_stages": F,
        "hot_stages": S_hot,
        "phase_a_round_ticks": a_round,
        "phase_a_saved_ticks": saved,
    }
