"""Chunked RWKV-6 wkv recurrence as a Pallas TPU kernel.

GPU RWKV kernels (the official CUDA wkv6) assign one thread per channel and step
time serially with the state in registers. The TPU-native re-think: the recurrence
factorizes into per-chunk *matmuls* (MXU work) plus an O(S/L) state hand-off —

  out_t = (r_t o e^{ca_{t-1}}) S_0 + sum_{s<t} (r_t o e^{ca_{t-1}-ca_s}) k_s v_s^T
          + (r_t o u o k_t) v_t
  S_L   = e^{ca_L} o S_0 + sum_s (k_s o e^{ca_L - ca_s}) v_s^T

with ca = cumsum(log w) held in VMEM, all exponents <= 0 (no overflow), and the
[L, L] pairwise-decay attention-like matrix built per chunk in VMEM. The grid is
(heads, chunks) with the chunk dimension sequential; the running state lives in a
VMEM scratch accumulator across grid steps — HBM sees each token exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu



def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, out_ref, sT_ref,
            state, *, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0]                       # [L, hd] fp32
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]
    u = u_ref[0]                       # [1, hd]
    s0 = state[...]                    # [hd, hd]

    ca = jnp.cumsum(lw, axis=0)        # inclusive log-decay prefix
    ca_prev = ca - lw

    inter = jax.lax.dot(r * jnp.exp(ca_prev), s0,
                        preferred_element_type=jnp.float32)
    L = r.shape[0]
    diff = ca_prev[:, None, :] - ca[None, :, :]            # [L, L, hd]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    P = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("tk,tsk,sk->ts", r, P, k,
                   preferred_element_type=jnp.float32)
    intra = jax.lax.dot(A, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    out_ref[0] = inter + intra + diag

    decay_all = jnp.exp(ca[-1])                            # [hd]
    carry_k = k * jnp.exp(ca[-1][None, :] - ca)
    new_state = decay_all[:, None] * s0 + jax.lax.dot(
        carry_k.T, v, preferred_element_type=jnp.float32)
    state[...] = new_state

    @pl.when(c == n_chunks - 1)
    def _fin():
        sT_ref[0] = new_state


def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, lw: jax.Array,
              u: jax.Array, state0: jax.Array, *, chunk: int = 32,
              interpret: bool = True):
    """r,k,v,lw [N, S, hd] fp32; u [N, 1, hd]; state0 [N, hd, hd].

    Returns (out [N, S, hd], state [N, hd, hd]).
    """
    N, S, hd = r.shape
    if S % chunk != 0:
        for c2 in range(min(chunk, S), 0, -1):
            if S % c2 == 0:
                chunk = c2
                break
    n_chunks = S // chunk

    grid = (N, n_chunks)
    tile = lambda: pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0))
    out, sT = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            tile(), tile(), tile(), tile(),
            pl.BlockSpec((1, 1, hd), lambda n, c: (n, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda n, c: (n, 0, 0)),
        ],
        out_specs=[
            tile(),
            pl.BlockSpec((1, hd, hd), lambda n, c: (n, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((N, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, state0)
    return out, sT
