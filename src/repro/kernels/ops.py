"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the kernel body
executes in Python for correctness validation; on TPU they lower to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import adapter_fused as _af
from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv_scan as _rs


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("activation",))
def adapter_fused(h: jax.Array, w_down: jax.Array, w_up: jax.Array, *,
                  activation: str = "gelu") -> jax.Array:
    """h [..., D] — leading dims flattened for the kernel and restored."""
    shape = h.shape
    h2 = h.reshape(-1, shape[-1])
    out = _af.adapter_fused(h2, w_down, w_up, activation=activation,
                            interpret=_interpret())
    return out.reshape(shape)


@jax.jit
def rwkv_scan(r, k, v, lw, u, state0):
    return _rs.rwkv_scan(r, k, v, lw, u, state0, interpret=_interpret())


@partial(jax.jit, static_argnames=("group", "causal", "window"))
def flash_attention(q, k, v, *, group: int = 1, causal: bool = True,
                    window=None):
    return _fa.flash_attention(q, k, v, group=group, causal=causal,
                               window=window, interpret=_interpret())


@jax.jit
def mamba_scan(log_a, b, c):
    from repro.kernels import mamba_scan as _ms

    return _ms.mamba_scan(log_a, b, c, interpret=_interpret())
