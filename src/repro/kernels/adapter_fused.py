"""Fused serial-adapter Pallas kernel (the paper's eq. (1) as one VMEM pass).

    out = h + act(h @ W_down) @ W_up

The adapter bottleneck is tiny (m = 48..64), so the unfused jnp version is
HBM-bound: it streams h [T, D] three times (down-proj read, up-proj write,
residual add) plus the [T, m] intermediate. Fusing keeps the [bt, m] intermediate
in VMEM and streams h exactly once in, once out — the arithmetic intensity of the
adapter rises from ~2m/3 to ~2m flops/byte, and both weight matrices (D*m each,
~0.6 MB at D=4608) stay VMEM-resident across the whole grid.

Tiling: grid over token tiles (bt x D); weights use a constant index_map so Mosaic
hoists their HBM->VMEM copy out of the loop. MXU alignment: bt multiple of 128,
m padded to 128 lanes by Mosaic internally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _act(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu}[name]


def _kernel(h_ref, wd_ref, wu_ref, out_ref, *, activation: str):
    h = h_ref[...]
    hf = h.astype(jnp.float32)
    mid = _act(activation)(
        jax.lax.dot(hf, wd_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32))
    up = jax.lax.dot(mid, wu_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out_ref[...] = h + up.astype(h.dtype)


def adapter_fused(h: jax.Array, w_down: jax.Array, w_up: jax.Array, *,
                  activation: str = "gelu", block_t: int = 256,
                  interpret: bool = True) -> jax.Array:
    """h [T, D] (callers flatten leading dims); returns h + adapter(h)."""
    T, D = h.shape
    m = w_down.shape[1]
    if T % block_t != 0:
        # pad to a tile multiple; masked rows are discarded on return
        pad = block_t - T % block_t
        hp = jnp.pad(h, ((0, pad), (0, 0)))
        return adapter_fused(hp, w_down, w_up, activation=activation,
                             block_t=block_t, interpret=interpret)[:T]

    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((D, m), lambda i: (0, 0)),
            pl.BlockSpec((m, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), h.dtype),
        interpret=interpret,
    )(h, w_down, w_up)
