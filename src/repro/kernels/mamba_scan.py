"""Chunked selective-SSM (Mamba) scan as a Pallas TPU kernel — Hymba's SSM half.

The GPU reference implementation (mamba's CUDA selective_scan) parallelizes over
channels with one thread per channel stepping time serially. TPU re-think: within
a chunk of length L the recurrence

    s_t = a_t * s_{t-1} + b_t        (elementwise over [d_inner, N])
    y_t = <s_t, c_t>                 (contraction over N)

factorizes with cumulative products  A_t = prod_{u<=t} a_u  (computed in log space
in VMEM) as  s_t = A_t * (s_0 + sum_{u<=t} b_u / A_u),  so a chunk becomes two
cumulative ops + one [L, N] contraction — VPU-friendly elementwise work with the
running state held in VMEM scratch across the sequential chunk grid dimension,
HBM touched once per token.

Numerics: a_t = exp(dt_t * A) in (0, 1]; cumprods underflow for long chunks, so
the kernel computes  s_t = A_t s_0 + sum_u exp(log A_t - log A_u) b_u  with all
exponents <= 0 via the pairwise [L, L] decay matrix per channel block (same safe
pattern as the RWKV-6 kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, b_ref, c_ref, out_ref, sT_ref, state, *, n_chunks: int):
    ch = pl.program_id(1)

    @pl.when(ch == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    la = la_ref[0]                       # [L, D, N] log a_t  (<= 0)
    b = b_ref[0]                         # [L, D, N]
    c = c_ref[0]                         # [L, N]
    s0 = state[...]                      # [D, N]

    cum = jnp.cumsum(la, axis=0)         # log A_t (inclusive)
    L = la.shape[0]
    # pairwise decay exp(cum_t - cum_u) for u <= t  (exponents <= 0: safe)
    diff = cum[:, None] - cum[None, :]                     # [L, L, D, N]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    P = jnp.where(tri[:, :, None, None], jnp.exp(diff), 0.0)
    inner = jnp.einsum("tudn,udn->tdn", P, b)              # sum_u<=t decay * b_u
    states = jnp.exp(cum) * s0[None] + inner               # [L, D, N]
    out_ref[0] = jnp.einsum("tdn,tn->td", states, c)
    state[...] = states[-1]

    @pl.when(ch == n_chunks - 1)
    def _fin():
        sT_ref[0] = states[-1]


def mamba_scan(log_a: jax.Array, b: jax.Array, c: jax.Array, *,
               chunk: int = 16, interpret: bool = True):
    """log_a, b: [B, S, D, N] (log decay <= 0, input); c: [B, S, N].

    Returns (y [B, S, D], state [B, D, N]). Grid (B, S/chunk) with the chunk
    dimension sequential; running state in VMEM scratch.
    """
    B, S, D, N = log_a.shape
    if S % chunk != 0:
        for c2 in range(min(chunk, S), 0, -1):
            if S % c2 == 0:
                chunk = c2
                break
    n_chunks = S // chunk

    tile4 = lambda: pl.BlockSpec((1, chunk, D, N), lambda i, j: (i, j, 0, 0))
    y, sT = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks),
        grid=(B, n_chunks),
        in_specs=[
            tile4(), tile4(),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, N), jnp.float32)],
        interpret=interpret,
    )(log_a, b, c)
    return y, sT
