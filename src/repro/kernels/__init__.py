"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three pieces:
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     — jit'd public wrappers (interpret=True on CPU, Mosaic on TPU)
  ref.py     — pure-jnp oracles (the allclose ground truth in tests)

Kernels: adapter_fused (the paper's eq. (1) as one VMEM pass), rwkv_scan
(RWKV-6 chunked wkv), flash_attention (GQA/window-aware online softmax),
mamba_scan (chunked selective SSM).
"""
