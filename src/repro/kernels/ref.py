"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _act(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu}[name]


def adapter_fused(h: Array, w_down: Array, w_up: Array, *,
                  activation: str = "gelu") -> Array:
    """h [..., D]; eq. (1): h + act(h @ Wd) @ Wu, fp32 internals."""
    hf = h.astype(jnp.float32)
    mid = _act(activation)(hf @ w_down.astype(jnp.float32))
    return h + (mid @ w_up.astype(jnp.float32)).astype(h.dtype)


def rwkv_scan(r: Array, k: Array, v: Array, lw: Array, u: Array,
              state0: Array):
    """Sequential RWKV-6 wkv recurrence (the definitional oracle).

    r,k,v,lw: [N, S, hd] fp32 (lw = log decay <= 0); u: [N, 1, hd];
    state0: [N, hd, hd]. Returns (out [N, S, hd], state [N, hd, hd]).

        out_t = r_t (S_{t-1} + u o k_t v_t^T);  S_t = w_t o S_{t-1} + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, lwt = xs
        kv = jnp.einsum("nk,nv->nkv", kt, vt)
        out = jnp.einsum("nk,nkv->nv", rt, s + u[:, 0, :, None] * kv)
        s2 = jnp.exp(lwt)[:, :, None] * s + kv
        return s2, out

    state, outs = jax.lax.scan(
        step, state0,
        (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         lw.swapaxes(0, 1)))
    return outs.swapaxes(0, 1), state


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None) -> Array:
    """q [N, Sq, hd]; k,v [N, Sk, hd] (kv heads pre-aligned). fp32 softmax."""
    Sq, Sk = q.shape[1], k.shape[1]
    s = jnp.einsum("nqh,nkh->nqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)    # align last query with last key
    ki = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= (qi - ki) < window
    s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(m[None], p, 0.0)
    return jnp.einsum("nqk,nkh->nqh", p.astype(v.dtype), v)


def mamba_scan(log_a: Array, b: Array, c: Array):
    """Sequential selective-SSM oracle. log_a, b: [B,S,D,N]; c: [B,S,N].

        s_t = exp(log_a_t) * s_{t-1} + b_t ;  y_t = sum_N s_t * c_t
    """
    B, S, D, N = log_a.shape

    def step(s, xs):
        la_t, b_t, c_t = xs
        s2 = jnp.exp(la_t) * s + b_t
        y = jnp.einsum("bdn,bn->bd", s2, c_t)
        return s2, y

    s0 = jnp.zeros((B, D, N), jnp.float32)
    sT, ys = jax.lax.scan(step, s0, (log_a.swapaxes(0, 1), b.swapaxes(0, 1),
                                     c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), sT
