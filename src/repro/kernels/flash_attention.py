"""Flash attention (forward) as a Pallas TPU kernel, GQA- and window-aware.

The GPU flash algorithm is a warp-level streaming softmax; the TPU re-think keeps
the same online-softmax math but tiles for the MXU: [bq x hd] @ [hd x bk] score
tiles, fp32 accumulators (m, l, acc) in VMEM scratch persisting across the
sequential k-block grid dimension, and GQA expressed through the k/v BlockSpec
index_map (``h // group``) so grouped heads never materialize repeated K/V in HBM.
Sliding windows mask per-tile; fully-masked tiles still execute (structural
simplification — skipping them via a shortened k-grid is a recorded beyond-paper
optimization opportunity, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, bq: int, bk: int,
            n_kb: int, seq_q: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (seq_k - seq_q)                                # align ends
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc

    @pl.when(ik == n_kb - 1)
    def _fin():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    group: int = 1, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q [Nq, Sq, hd]; k,v [Nk, Sk, hd] with Nq == Nk * group (GQA).

    Returns [Nq, Sq, hd]. Softmax in fp32, online (flash) accumulation.
    """
    Nq, Sq, hd = q.shape
    Nk, Sk, _ = k.shape
    assert Nq == Nk * group, (Nq, Nk, group)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_qb, n_kb = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, n_kb=n_kb, seq_q=Sq, seq_k=Sk),
        grid=(Nq, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda n, iq, ik: (n, iq, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda n, iq, ik, group=group: (n // group, ik, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda n, iq, ik, group=group: (n // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda n, iq, ik: (n, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((Nq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
