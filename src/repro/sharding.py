"""Logical-axis -> mesh-axis sharding rules.

Every parameter/activation dimension carries a *logical* axis name; rules translate
logical names into mesh axes for the active mesh. Production meshes:

  * single pod : (data=16, model=16)                      -- 256 chips
  * multi pod  : (pod=2, data=16, model=16)               -- 512 chips

Weights are Megatron-sharded on ``model`` (heads / ffn / experts / vocab) and
FSDP-sharded on the data axes (``embed`` dim), so the 400B-scale MoE fits.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------


def default_rules(mesh: Mesh) -> Dict[str, MeshAxes]:
    """Logical axis name -> mesh axes, adapted to which axes the mesh has."""
    axes = mesh.axis_names
    fsdp: MeshAxes = ("pod", "data") if "pod" in axes else ("data",)
    model: MeshAxes = "model" if "model" in axes else None
    batch: MeshAxes = ("pod", "data") if "pod" in axes else ("data",)
    return {
        "_axis_sizes": {name: mesh.shape[name] for name in axes},
        # ---- weights ----
        "vocab": model,          # embedding / lm head vocab dim
        "embed": fsdp,           # d_model dim of weights => FSDP all-gather at use
        "heads": model,
        "kv_heads": model,
        "head_dim": None,
        "ffn": model,            # Megatron column/row parallel
        "experts": model,        # expert parallelism
        "expert_ffn": None,
        "expert_embed": None,    # small-expert MoE: no FSDP on d_model dim
        "bottleneck": None,      # adapter m
        "layers": None,          # stacked-scan leading axes
        "state": None,           # SSM state dims
        "conv": None,
        "lora": None,
        "pos": None,
        "norm": None,
        # ---- activations ----
        "batch": batch,
        "seq": None,
        "act_embed": model,      # d_model dim of activations (tensor-parallel)
        "act_heads": model,
        "kv_seq": (("data", "model") if "model" in axes else ("data",))
        if "data" in axes else model,   # KV cache seq: data then model
        "frontend_seq": None,
    }


def spec_for(logical: Sequence[Optional[str]],
             rules: Dict[str, MeshAxes],
             shape: Optional[Sequence[int]] = None) -> P:
    """Translate logical axis names into a PartitionSpec.

    With ``shape`` given, a mesh axis is only assigned to a dimension whose size
    it divides (pjit rejects uneven *explicit* input shardings — e.g. kv_heads=8
    cannot shard over model=16 and is replicated instead, the Megatron GQA rule).
    For tuple axes, the longest divisible prefix is kept.
    """
    sizes: Dict[str, int] = rules.get("_axis_sizes", {})
    used: set = set()
    parts = []
    for i, name in enumerate(logical):
        if name is None:
            parts.append(None)
            continue
        ax = rules.get(name, None)
        if ax is None:
            parts.append(None)
            continue
        # never assign the same mesh axis twice in one spec
        flat = (ax,) if isinstance(ax, str) else tuple(ax)
        flat = tuple(a for a in flat if a not in used)
        if shape is not None and sizes:
            dim = shape[i]
            keep = []
            prod = 1
            for a in flat:
                if dim % (prod * sizes.get(a, 1)) == 0:
                    keep.append(a)
                    prod *= sizes.get(a, 1)
                else:
                    break
            flat = tuple(keep)
        if not flat:
            parts.append(None)
            continue
        used.update(flat)
        parts.append(flat[0] if len(flat) == 1 else flat)
    return P(*parts)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Convenience: divide batch across data axes, validating divisibility softly
# ---------------------------------------------------------------------------

def batch_spec(rules: Dict[str, MeshAxes]) -> P:
    return spec_for(("batch", None), rules)


def data_axis_size(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n
