"""jax version-compatibility shims.

The repo targets the modern jax API surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``) but must also run on the
jax 0.4.x line baked into CI containers, where those names live under
``jax.experimental`` or do not exist yet.  Every call site in the repo
goes through this module instead of feature-testing jax inline.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    class AxisType:  # type: ignore[no-redef]
        """Placeholder: pre-AxisType jax treats every mesh axis as Auto."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except TypeError:  # jax 0.4.x: no axis_types kwarg
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def set_mesh(mesh) -> Any:
    """Context manager activating ``mesh`` (jax.set_mesh, or Mesh itself)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # jax 0.4.x: Mesh is its own context manager.
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a dict.

    jaxlib < 0.5 returns ``[dict]`` (one per device program); newer versions
    return the dict directly."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` or the jax 0.4.x experimental equivalent.

    The fallback disables replication checking: the ring round takes
    ``jax.value_and_grad`` *inside* the mapped body (collective transposes for
    ``all_gather``/``psum`` are well-defined but the old rep-checker cannot
    prove replication through them).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
