"""RingAda reproduction: pipelined PEFT fine-tuning with scheduled layer unfreezing.

Multi-pod JAX framework implementing Li, Chen & Wu, "RingAda: Pipelining Large
Model Fine-Tuning on Edge Devices with Scheduled Layer Unfreezing" (CS.DC 2025),
adapted to TPU SPMD (see DESIGN.md).
"""
__version__ = "1.0.0"
