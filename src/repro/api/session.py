"""RingSession: ONE pluggable training API over backends, policies, caching.

The paper's system is one coherent loop — ring pipeline, top-down scheduled
unfreezing, early-stopped backprop — and this facade is its single entry
point.  Every execution path is a :mod:`~repro.api.backends` adapter, every
unfreeze rule a :mod:`~repro.api.policies` policy, and a new scenario is a
~50-line plugin instead of a new driver:

    from repro.api import RingSession, LossPlateauPolicy

    sess = RingSession.create(cfg, tc, backend="cached", slots_per_epoch=8,
                              policy=LossPlateauPolicy(patience=3))
    history = sess.run(64, log_every=8)        # list of metric dicts
    sess.save("ckpt/ring")                     # params + Adam moments +
                                               # policy + data cursor
    sess2 = RingSession.restore("ckpt/ring", cfg, tc,
                                policy=LossPlateauPolicy(patience=3))
    sess2.run(64)                              # continues bit-identically

Contracts the session enforces (on top of the per-backend ones documented in
``backends.py``):

  * **monotone boundary** — the boundary reported by every step may never
    increase, whatever policy produced it; violations raise immediately
    (the activation cache's invalidation model depends on this, see
    ``core/unfreeze.py``);
  * **async metrics** — fused-backend metrics stay on device between logging
    intervals; ``run`` materializes them in batches.  A loss-driven policy
    (``wants_loss=True``) opts into one host sync per round — the documented
    price of adaptive unfreezing;
  * **bit-reproducible resume** — ``save`` persists params, optimizer
    moments, the policy's host state, the data cursor, and the step counter;
    ``restore`` + ``run`` replays exactly what the uninterrupted run would
    have produced (pinned by tests/test_api_session.py).
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, TrainConfig

from .backends import (CachedBackend, FusedBackend, PjitBackend,
                       ReferenceBackend)
from .data import PjitDataSource, RingDataSource
from .metrics import Callback, RoundMetrics
from .policies import resolve_policy

BACKENDS = {"reference": ReferenceBackend, "fused": FusedBackend,
            "cached": CachedBackend, "pjit": PjitBackend}


class RingSession:
    """Facade over (backend, policy, data); build with :meth:`create` or
    :meth:`restore`, drive with :meth:`step` / :meth:`run`."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, backend, policy,
                 data, *, callbacks: Sequence[Callback] = (),
                 create_args: Optional[Dict[str, Any]] = None):
        self.cfg, self.tc = cfg, tc
        self.backend, self.policy, self.data = backend, policy, data
        self.callbacks: List[Callback] = list(callbacks)
        self.step_count = 0
        self._last_boundary: Optional[int] = None
        self._create_args = create_args or {"backend": backend.name}

    # ------------------------------------------------------------------
    @classmethod
    def create(cls, cfg: ModelConfig, tc: TrainConfig, *,
               backend: Any = "fused", policy: Any = None,
               n_stages: Optional[int] = None,
               slots_per_epoch: Optional[int] = None,
               cache_capacity: Optional[int] = None,
               packed: bool = True, cache_dtype: str = "native",
               impl: str = "jnp", params: Optional[Dict[str, Any]] = None,
               spans: Any = None, device_profiles: Any = None,
               data: Any = None, callbacks: Sequence[Callback] = (),
               log=print) -> "RingSession":
        """Wire a session from names: backend in {'pjit', 'reference',
        'fused', 'cached'} (or a ready Backend instance), policy in
        {'interval', 'plateau', None=paper rule} (or an UnfreezePolicy).

        ``cached`` needs ``slots_per_epoch`` (the cache's key space);
        ``cache_capacity`` defaults to it.  ``packed`` (fused/cached) selects
        the packed-conveyor Phase A (one ``S*M + F - 1``-tick stream per
        round; False = the per-owner scan, kept for A/B benchmarking);
        ``cache_dtype`` in {'native', 'f32', 'bf16', 'int8'} compresses the
        activation-cache entries (bf16 halves, int8 quarters the bytes per
        entry).  ``data=None`` builds the standard synthetic per-client
        datasets exactly as ``launch/train.py`` always did, so session runs
        are comparable to the seed drivers.

        Heterogeneous rings (ring backends only): ``device_profiles`` — one
        speed (float) or ``partition.DeviceProfile`` per stage, in ring order
        — runs the paper's Algorithm-1 speed-weighted block assignment
        (e.g. speeds ``[1.0, 1.25, 0.5, 0.75]`` over 14 blocks give the
        paper's 4:5:2:3 spans); ``spans`` pins an explicit layout (sizes
        list like ``[4, 5, 2, 3]`` or ``[(begin, end)]`` pairs) and wins
        over profiles.  The layout rides in checkpoints and must match on
        restore (the stage-stacked Adam moments are laid out per span).
        """
        policy = resolve_policy(policy, tc)
        S = n_stages or tc.n_stages
        if isinstance(backend, str):
            if backend not in BACKENDS:
                raise ValueError(f"unknown backend {backend!r}; "
                                 f"known: {sorted(BACKENDS)}")
            if backend == "pjit" and (spans is not None
                                      or device_profiles is not None):
                raise ValueError(
                    "spans/device_profiles describe the ring's stage layout "
                    "— they have no meaning for the pjit backend")
            if backend == "pjit":
                be = PjitBackend(cfg, tc, policy, impl=impl, params=params)
            elif backend == "cached":
                if not slots_per_epoch:
                    raise ValueError(
                        "backend='cached' needs slots_per_epoch >= 1: the "
                        "activation cache keys on stable batch slots — with "
                        "streaming draws no key ever repeats. Use "
                        "backend='fused' for non-repeating data.")
                cap = (cache_capacity if cache_capacity is not None
                       else slots_per_epoch)
                if 0 < cap < slots_per_epoch:
                    # round-robin slots + LRU: every slot is evicted before
                    # its revisit — all capture cost, zero hits
                    log(f"WARNING: cache_capacity {cap} < slots_per_epoch "
                        f"{slots_per_epoch}: the cache will thrash (0% hits, "
                        f"capture overhead every round) — raise the capacity "
                        f"or use backend='fused'")
                be = CachedBackend(cfg, tc, policy, n_stages=S,
                                   cache_capacity=cap, params=params,
                                   packed=packed, cache_dtype=cache_dtype,
                                   spans=spans,
                                   device_profiles=device_profiles)
            elif backend == "fused":
                be = FusedBackend(cfg, tc, policy, n_stages=S, params=params,
                                  packed=packed, cache_dtype=cache_dtype,
                                  spans=spans,
                                  device_profiles=device_profiles)
            else:
                be = BACKENDS[backend](cfg, tc, policy, n_stages=S,
                                       params=params, spans=spans,
                                       device_profiles=device_profiles)
        else:
            be = backend
            # a ready instance already embeds the policy that drives its
            # schedule — that object MUST also be the one the session
            # observes losses into, or a loss-driven policy would never
            # unfreeze (and the monotone check would blame the wrong rule).
            policy = getattr(be, "policy", policy)
            if isinstance(be, CachedBackend) and data is None \
                    and not slots_per_epoch:
                raise ValueError(
                    "a CachedBackend needs slot-keyed batches: pass "
                    "slots_per_epoch (for the default data source) or a "
                    "slot-yielding data= — with streaming draws every round "
                    "would silently bypass the cache (0% hits)")
        if data is None:
            data = (PjitDataSource(cfg, tc) if be.kind == "pjit"
                    else RingDataSource(cfg, tc, getattr(be, "S", S),
                                        slots_per_epoch=slots_per_epoch))
        be_spans = getattr(be, "spans", None)
        create_args = {"backend": be.name, "n_stages": getattr(be, "S", None),
                       "slots_per_epoch": slots_per_epoch,
                       "cache_capacity": cache_capacity, "impl": impl,
                       "packed": packed, "cache_dtype": cache_dtype,
                       # span layout rides in the checkpoint so restore
                       # rebuilds the same heterogeneous partition (JSON:
                       # list of [begin, end] pairs)
                       "spans": ([list(sp) for sp in be_spans]
                                 if be_spans is not None else None)}
        return cls(cfg, tc, be, policy, data, callbacks=callbacks,
                   create_args=create_args)

    # ------------------------------------------------------------------
    def step(self, batch: Any = None) -> RoundMetrics:
        """One backend step (a full ring round for ring backends, one
        optimizer step for pjit).  Returns possibly-device metrics; call
        ``.materialize()`` (or use :meth:`run`) to host-sync them."""
        if batch is None:
            batch = self.data.next()
        raw = self.backend.step(batch)
        boundary = raw["boundary"]
        if self._last_boundary is not None and boundary > self._last_boundary:
            raise RuntimeError(
                f"unfreeze boundary increased {self._last_boundary} -> "
                f"{boundary} at step {raw['step']} (policy "
                f"{self.policy!r}): RingAda schedules are monotone top-down "
                f"and the activation cache's invalidation contract depends "
                f"on it (see core/unfreeze.py)")
        self._last_boundary = boundary
        self.step_count = raw["step"]
        m = RoundMetrics(step=raw["step"], boundary=boundary,
                         depth=raw["depth"], loss=raw["loss"],
                         compile_count=self.backend.compile_count,
                         tokens=raw.get("tokens", 0),
                         cache=raw.get("cache"),
                         cache_hit=raw.get("cache_hit"),
                         extras=raw.get("extras", {}))
        if self.policy.wants_loss:
            m = m.materialize()            # adaptive policies pay 1 sync/round
            self.policy.observe(self.step_count, m.loss)
        return m

    def run(self, steps: int, *, log_every: int = 1,
            callbacks: Optional[Sequence[Callback]] = None,
            ) -> List[Dict[str, Any]]:
        """Drive ``steps`` backend steps off the session's data source.

        Metrics are materialized once per ``log_every`` interval (the fused
        async-dispatch contract) and EVERY step lands in the returned history
        (as flat dicts).  Callbacks fire per materialized step.
        """
        cbs = self.callbacks + list(callbacks or [])
        for cb in cbs:
            cb.on_start(self)
        history: List[Dict[str, Any]] = []
        pending: List[RoundMetrics] = []
        t0 = last_t = time.time()
        tokens_acc = 0

        def flush():
            nonlocal last_t, tokens_acc
            now = time.time()
            dt = now - last_t
            tps = tokens_acc / dt if dt > 0 and tokens_acc else None
            for pm in pending:
                mm = pm.materialize(wall_s=round(now - t0, 2),
                                    tokens_per_sec=tps)
                history.append(mm.to_dict())
                for cb in cbs:
                    cb.on_round(self, mm)
            pending.clear()
            last_t, tokens_acc = now, 0

        for i in range(steps):
            m = self.step()
            pending.append(m)
            tokens_acc += m.tokens
            if i % log_every == 0 or i == steps - 1:
                flush()
        flush()
        for cb in cbs:
            cb.on_end(self, history)
        return history

    # ------------------------------------------------------------------
    def export_params(self) -> Dict[str, Any]:
        """Canonical full param tree ([R, ...] block stack), any backend."""
        return self.backend.export_params()

    def save(self, path: str) -> None:
        """Persist the complete resumable state: params + Adam moments (via
        ``checkpoint.save(..., opt_state=...)``), the policy's host state,
        the data cursor, and the step counter.  Adapter-only params payload
        (the backbone is frozen + seed-derived, so it reconstructs exactly)."""
        st = self.backend.state()
        extra = {
            "session": "RingSession/v1",
            "format": st["format"],
            "seed": self.tc.seed,
            "last_boundary": self._last_boundary,
            "policy": {"type": type(self.policy).__name__,
                       "state": self.policy.state()},
            "data": self.data.state(),
            **self._create_args,
        }
        ckpt.save(path, st["params"], step=self.step_count,
                  opt_state=st["opt"], adapters_only=True, extra=extra)

    def load(self, path: str) -> "RingSession":
        """Load a checkpoint into this (freshly created, same-config)
        session.  Raises on backend-format or policy-type mismatch instead of
        silently reinterpreting moments."""
        st = self.backend.state()
        params, meta = ckpt.restore(path, st["params"])
        ex = meta["extra"]
        if ex.get("format") != st["format"]:
            raise ValueError(
                f"checkpoint {path!r} was saved by a {ex.get('format')!r} "
                f"backend but this session runs {st['format']!r} — optimizer "
                f"moments are laid out per-format (stage-stacked vs full-"
                f"size) and cannot be reinterpreted across families. "
                f"Recreate the session with the saved backend.")
        saved_policy = ex.get("policy", {})
        if saved_policy.get("type") != type(self.policy).__name__:
            raise ValueError(
                f"checkpoint {path!r} was driven by policy "
                f"{saved_policy.get('type')!r} but this session has "
                f"{type(self.policy).__name__!r} — pass the matching policy "
                f"to restore() so the depth sequence continues correctly.")
        opt = ckpt.restore_opt(path, st["opt"])
        self.backend.load_state(params, opt, step=meta["step"])
        self.policy.load_state(saved_policy.get("state", {}))
        self.data.load_state(ex["data"])
        self.step_count = meta["step"]
        self._last_boundary = ex.get("last_boundary")
        return self

    @classmethod
    def restore(cls, path: str, cfg: ModelConfig, tc: TrainConfig, *,
                policy: Any = None, backend: Any = None,
                **create_kwargs) -> "RingSession":
        """Rebuild a session from a checkpoint.  Backend/shape arguments
        default to what the checkpoint recorded; the policy must be supplied
        with the same type it was saved with (its host state is restored)."""
        with open(path + ".json") as f:
            meta = json.load(f)
        ex = meta["extra"]
        if backend is None:
            backend = ex.get("backend", "fused")
        for k in ("n_stages", "slots_per_epoch", "cache_capacity", "impl",
                  "packed", "cache_dtype", "spans"):
            if k in ex and ex[k] is not None:
                create_kwargs.setdefault(k, ex[k])
        if backend == "pjit":
            # a ring checkpoint's span layout means nothing to pjit; let the
            # format-mismatch check produce the real diagnostic
            create_kwargs.pop("spans", None)
        sess = cls.create(cfg, tc, backend=backend, policy=policy,
                          **create_kwargs)
        return sess.load(path)
