"""RingSession: ONE pluggable training API over backends, policies, caching.

The paper's system is one coherent loop — ring pipeline, top-down scheduled
unfreezing, early-stopped backprop — and this facade is its single entry
point.  Every execution path is a :mod:`~repro.api.backends` adapter, every
unfreeze rule a :mod:`~repro.api.policies` policy, and a new scenario is a
~50-line plugin instead of a new driver:

    from repro.api import RingSession, LossPlateauPolicy

    sess = RingSession.create(cfg, tc, backend="cached", slots_per_epoch=8,
                              policy=LossPlateauPolicy(patience=3))
    history = sess.run(64, log_every=8)        # list of metric dicts
    sess.save("ckpt/ring")                     # params + Adam moments +
                                               # policy + data cursor
    sess2 = RingSession.restore("ckpt/ring", cfg, tc,
                                policy=LossPlateauPolicy(patience=3))
    sess2.run(64)                              # continues bit-identically

Contracts the session enforces (on top of the per-backend ones documented in
``backends.py``):

  * **monotone boundary** — the boundary reported by every step may never
    increase, whatever policy produced it; violations raise immediately
    (the activation cache's invalidation model depends on this, see
    ``core/unfreeze.py``);
  * **async metrics** — fused-backend metrics stay on device between logging
    intervals; ``run`` materializes them in batches.  A loss-driven policy
    (``wants_loss=True``) opts into one host sync per round — the documented
    price of adaptive unfreezing;
  * **bit-reproducible resume** — ``save`` persists params, optimizer
    moments, the policy's host state, the data cursor, and the step counter;
    ``restore`` + ``run`` replays exactly what the uninterrupted run would
    have produced (pinned by tests/test_api_session.py).
"""
from __future__ import annotations

import json
import time
import warnings
import weakref
from typing import Any, Dict, List, Optional, Sequence

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.elastic import parse_chaos_events
from repro.core.partition import parse_device_profiles, spans_from_profiles
from repro.core.simulator import ChurnEvent

from .backends import (CachedBackend, ChaosBackend, FusedBackend, PjitBackend,
                       ReferenceBackend)
from .data import PjitDataSource, RingDataSource
from .metrics import Callback, RoundMetrics
from .policies import resolve_policy
from .tenants import TenantGroup

BACKENDS = {"reference": ReferenceBackend, "fused": FusedBackend,
            "cached": CachedBackend, "pjit": PjitBackend}


class RingSession:
    """Facade over (backend, policy, data); build with :meth:`create` or
    :meth:`restore`, drive with :meth:`step` / :meth:`run`."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, backend, policy,
                 data, *, callbacks: Sequence[Callback] = (),
                 create_args: Optional[Dict[str, Any]] = None):
        self.cfg, self.tc = cfg, tc
        self.backend, self.policy, self.data = backend, policy, data
        self.callbacks: List[Callback] = list(callbacks)
        self.step_count = 0
        self._last_boundary: Optional[int] = None
        self._create_args = create_args or {"backend": backend.name}
        # every un-materialized RoundMetrics this session has handed out —
        # flushed (host-synced in place) before any donation-invalidating
        # backend call (repartition / load), see flush_metrics()
        self._live_metrics: "weakref.WeakSet[RoundMetrics]" = weakref.WeakSet()
        # an elastic (chaos-wrapped) backend shrinks/repartitions INSIDE its
        # step() — it must flush pending device metrics first, and only the
        # session knows which ones are live
        if hasattr(backend, "flush_hook"):
            backend.flush_hook = self.flush_metrics

    # ------------------------------------------------------------------
    @classmethod
    def create(cls, cfg: ModelConfig, tc: TrainConfig, *,
               backend: Any = "fused", policy: Any = None,
               n_stages: Optional[int] = None,
               slots_per_epoch: Optional[int] = None,
               cache_capacity: Optional[int] = None,
               packed: bool = True, cache_dtype: str = "native",
               impl: str = "jnp", params: Optional[Dict[str, Any]] = None,
               spans: Any = None, device_profiles: Any = None,
               tenants: int = 1, elastic: bool = False, chaos: Any = (),
               data: Any = None, callbacks: Sequence[Callback] = (),
               log=print) -> "RingSession":
        """Wire a session from names: backend in {'pjit', 'reference',
        'fused', 'cached'} (or a ready Backend instance), policy in
        {'interval', 'plateau', None=paper rule} (or an UnfreezePolicy).
        Every named backend is built through ONE ``Backend.build`` call —
        each adapter validates or ignores the kwargs it doesn't support.

        ``cached`` needs ``slots_per_epoch`` (the cache's key space);
        ``cache_capacity`` defaults to it (x ``tenants``).  ``packed``
        (fused/cached) selects the packed-conveyor Phase A (one
        ``S*M + F - 1``-tick stream per round, ``T*S*M + F - 1`` with
        tenants; False = the per-owner scan, kept for A/B benchmarking);
        ``cache_dtype`` in {'native', 'f32', 'bf16', 'int8'} compresses the
        activation-cache entries (bf16 halves, int8 quarters the bytes per
        entry).  ``data=None`` builds the standard synthetic per-client
        datasets exactly as ``launch/train.py`` always did, so session runs
        are comparable to the seed drivers.

        Multi-tenant personalization (``tenants=T > 1``, fused/cached only):
        ONE frozen trunk serves T adapter sets — batches gain a tenant axis
        ([S, T, M, mb, seq], per-tenant data streams from seeds
        ``tc.seed + 7919*t``), metrics gain ``tenant_losses``, the cache
        partitions per tenant, and :attr:`tenants` exposes per-tenant
        :class:`~repro.api.tenants.TenantGroup` handles (save/load one
        tenant's adapters+moments through an ``AdapterStore``).  Per tenant,
        the joint session trains bit-identically to T independent
        single-tenant sessions (tests/test_tenants.py).

        Heterogeneous rings (ring backends only): ``device_profiles`` — one
        speed (float) or ``partition.DeviceProfile`` per stage, in ring order
        — runs the paper's Algorithm-1 speed-weighted block assignment
        (e.g. speeds ``[1.0, 1.25, 0.5, 0.75]`` over 14 blocks give the
        paper's 4:5:2:3 spans); ``spans`` pins an explicit layout (sizes
        list like ``[4, 5, 2, 3]`` or ``[(begin, end)]`` pairs) and wins
        over profiles.  The layout rides in checkpoints and must match on
        restore (the stage-stacked Adam moments are laid out per span).
        """
        policy = resolve_policy(policy, tc)
        S = n_stages or tc.n_stages
        if tenants < 1:
            raise ValueError(f"tenants must be >= 1, got {tenants}")
        if isinstance(backend, str):
            if backend not in BACKENDS:
                raise ValueError(f"unknown backend {backend!r}; "
                                 f"known: {sorted(BACKENDS)}")
            be = BACKENDS[backend].build(
                cfg, tc, policy, n_stages=S, spans=spans,
                device_profiles=device_profiles, params=params,
                slots_per_epoch=slots_per_epoch,
                cache_capacity=cache_capacity, packed=packed,
                cache_dtype=cache_dtype, impl=impl, tenants=tenants, log=log)
        else:
            be = backend
            # a ready instance already embeds the policy that drives its
            # schedule — that object MUST also be the one the session
            # observes losses into, or a loss-driven policy would never
            # unfreeze (and the monotone check would blame the wrong rule).
            policy = getattr(be, "policy", policy)
            if getattr(be, "T", 1) != tenants and tenants != 1:
                raise ValueError(
                    f"tenants={tenants} conflicts with the ready backend's "
                    f"T={getattr(be, 'T', 1)} — the instance decides")
            tenants = getattr(be, "T", 1)
            if isinstance(be, CachedBackend) and data is None \
                    and not slots_per_epoch:
                raise ValueError(
                    "a CachedBackend needs slot-keyed batches: pass "
                    "slots_per_epoch (for the default data source) or a "
                    "slot-yielding data= — with streaming draws every round "
                    "would silently bypass the cache (0% hits)")
        S0 = getattr(be, "S", S)           # pre-churn ring size
        if elastic or chaos:
            if be.kind == "pjit":
                raise ValueError(
                    "elastic/chaos is a ring feature — the pjit baseline has "
                    "no span layout to shrink or repartition")
            specs = [chaos] if isinstance(chaos, (str, ChurnEvent)) \
                else list(chaos)
            events = (list(parse_chaos_events(
                          [e for e in specs if isinstance(e, str)]))
                      + [e for e in specs if isinstance(e, ChurnEvent)])
            be = ChaosBackend(be, events=events, elastic=elastic,
                              device_profiles=device_profiles, log=log)
        if data is None:
            # an elastic ring keeps the ORIGINAL fanout: the source always
            # yields S0 client rows and ChaosBackend trims to survivors, so
            # the data cursor (and save -> resume) is churn-independent
            data = (PjitDataSource(cfg, tc) if be.kind == "pjit"
                    else RingDataSource(cfg, tc, S0,
                                        slots_per_epoch=slots_per_epoch,
                                        tenants=tenants))
        be_spans = getattr(be, "spans", None)
        create_args = {"backend": be.name,
                       # the ORIGINAL ring size: an elastic session's data
                       # source (and restore) is anchored to it even after
                       # churn shrinks the live ring below it
                       "n_stages": S0 if be.kind != "pjit" else None,
                       "slots_per_epoch": slots_per_epoch,
                       "cache_capacity": cache_capacity, "impl": impl,
                       "packed": packed, "cache_dtype": cache_dtype,
                       "tenants": tenants, "elastic": elastic,
                       # span layout rides in the checkpoint so restore
                       # rebuilds the same heterogeneous partition (JSON:
                       # list of [begin, end] pairs)
                       "spans": ([list(sp) for sp in be_spans]
                                 if be_spans is not None else None)}
        return cls(cfg, tc, be, policy, data, callbacks=callbacks,
                   create_args=create_args)

    # ------------------------------------------------------------------
    @property
    def n_tenants(self) -> int:
        return getattr(self.backend, "T", 1)

    @property
    def tenants(self) -> List[TenantGroup]:
        """Per-tenant handles (see :class:`~repro.api.tenants.TenantGroup`);
        a single-tenant session returns one group for tenant 0."""
        return [TenantGroup(self, t) for t in range(self.n_tenants)]

    # ------------------------------------------------------------------
    def step(self, batch: Any = None) -> RoundMetrics:
        """One backend step (a full ring round for ring backends, one
        optimizer step for pjit).  Returns possibly-device metrics; call
        ``.materialize()`` (or use :meth:`run`) to host-sync them."""
        if batch is None:
            batch = self.data.next()
        raw = self.backend.step(batch)
        if raw.get("layout_changed"):
            # an elastic shrink/grow/repartition happened INSIDE the step:
            # span edges (and so boundary alignment granularity) moved, so
            # the monotone check re-seeds from this round's boundary, the
            # checkpointed layout/membership follow the live ring, and a
            # plateau policy skips the recovery blip (geometry artifact,
            # not training signal)
            self._last_boundary = None
            be_spans = getattr(self.backend, "spans", None)
            self._create_args["spans"] = ([list(sp) for sp in be_spans]
                                          if be_spans is not None else None)
            surv = getattr(self.backend, "survivors", None)
            if surv is not None:
                self._create_args["survivors"] = list(surv)
            if hasattr(self.policy, "suspend"):
                self.policy.suspend(1)
        boundary = raw["boundary"]
        if self._last_boundary is not None and boundary > self._last_boundary:
            raise RuntimeError(
                f"unfreeze boundary increased {self._last_boundary} -> "
                f"{boundary} at step {raw['step']} (policy "
                f"{self.policy!r}): RingAda schedules are monotone top-down "
                f"and the activation cache's invalidation contract depends "
                f"on it (see core/unfreeze.py)")
        self._last_boundary = boundary
        self.step_count = raw["step"]
        m = RoundMetrics(step=raw["step"], boundary=boundary,
                         depth=raw["depth"], loss=raw["loss"],
                         compile_count=self.backend.compile_count,
                         tokens=raw.get("tokens", 0),
                         cache=raw.get("cache"),
                         cache_hit=raw.get("cache_hit"),
                         extras=raw.get("extras", {}))
        if self.policy.wants_loss:
            m = m.materialize()            # adaptive policies pay 1 sync/round
            self.policy.observe(self.step_count, m.loss)
        else:
            self._live_metrics.add(m)      # flushed before layout changes
        return m

    def flush_metrics(self) -> None:
        """Host-sync (in place) every un-materialized RoundMetrics this
        session has handed out.  Called before any backend operation that
        invalidates live device buffers (repartition's donated restack,
        checkpoint load): a history entry must never read post-swap bits."""
        for m in list(self._live_metrics):
            m.flush_()
        self._live_metrics.clear()

    def repartition(self, spans: Any) -> None:
        """Switch the ring's span layout mid-run (elastic membership /
        re-profiling).  Pending device metrics are flushed FIRST — the
        restack donates the live param/moment buffers, and a lazy metric
        materialized after that donation would read freed memory (pinned by
        tests/test_tenants.py)."""
        self.flush_metrics()
        self.backend.repartition(spans)
        be_spans = getattr(self.backend, "spans", None)
        self._create_args["spans"] = ([list(sp) for sp in be_spans]
                                      if be_spans is not None else None)

    def run(self, steps: int, *, log_every: int = 1,
            callbacks: Optional[Sequence[Callback]] = None,
            ) -> List[Dict[str, Any]]:
        """Drive ``steps`` backend steps off the session's data source.

        Metrics are materialized once per ``log_every`` interval (the fused
        async-dispatch contract) and EVERY step lands in the returned history
        (as flat dicts).  Callbacks fire per materialized step.
        """
        cbs = self.callbacks + list(callbacks or [])
        for cb in cbs:
            cb.on_start(self)
        history: List[Dict[str, Any]] = []
        pending: List[RoundMetrics] = []
        t0 = last_t = time.time()
        tokens_acc = 0

        def flush():
            nonlocal last_t, tokens_acc
            now = time.time()
            dt = now - last_t
            tps = tokens_acc / dt if dt > 0 and tokens_acc else None
            for pm in pending:
                mm = pm.materialize(wall_s=round(now - t0, 2),
                                    tokens_per_sec=tps)
                history.append(mm.to_dict())
                for cb in cbs:
                    cb.on_round(self, mm)
            pending.clear()
            last_t, tokens_acc = now, 0

        for i in range(steps):
            m = self.step()
            pending.append(m)
            tokens_acc += m.tokens
            if i % log_every == 0 or i == steps - 1:
                flush()
        flush()
        for cb in cbs:
            cb.on_end(self, history)
        return history

    # ------------------------------------------------------------------
    # persistence: the canonical surface is save(path) /
    # RingSession.restore(path, cfg, tc, ...) / export_adapters(tenant=...);
    # load() and export_params() remain as deprecated shims.
    # ------------------------------------------------------------------
    def export_adapters(self, tenant: int = 0) -> Dict[str, Any]:
        """One tenant's trainable set as a flat ``{"adapter", "head"}``
        bundle — the unit an :class:`~repro.api.tenants.AdapterStore`
        persists and serving hot-swaps.  Ring backends only (the pjit
        backend's trainable set isn't adapter-shaped)."""
        d = getattr(self.backend, "driver", None)
        if d is None or not hasattr(d, "export_adapters"):
            raise NotImplementedError(
                f"backend {self.backend.name!r} has no adapter bundle "
                f"surface; use backend.state() for its full params")
        return d.export_adapters(tenant)

    def export_params(self) -> Dict[str, Any]:
        """Deprecated: use ``backend.export_params()`` for the full canonical
        tree, or :meth:`export_adapters` for the trainable bundle."""
        warnings.warn(
            "RingSession.export_params() is deprecated — use "
            "session.backend.export_params() (full canonical tree) or "
            "session.export_adapters(tenant=...) (trainable bundle)",
            DeprecationWarning, stacklevel=2)
        return self.backend.export_params()

    def save(self, path: str) -> None:
        """Persist the complete resumable state: params + Adam moments (via
        ``checkpoint.save(..., opt_state=...)``), the policy's host state,
        the data cursor, and the step counter.  Adapter-only params payload
        (the backbone is frozen + seed-derived, so it reconstructs exactly)."""
        st = self.backend.state()
        extra = {
            "session": "RingSession/v1",
            "format": st["format"],
            "seed": self.tc.seed,
            "last_boundary": self._last_boundary,
            "policy": {"type": type(self.policy).__name__,
                       "state": self.policy.state()},
            "data": self.data.state(),
            **self._create_args,
        }
        ckpt.save(path, st["params"], step=self.step_count,
                  opt_state=st["opt"], adapters_only=True, extra=extra)

    def load(self, path: str) -> "RingSession":
        """Deprecated: use the classmethod :meth:`restore` — it rebuilds the
        session with the checkpoint's recorded shape arguments before
        loading, which this method cannot do."""
        warnings.warn(
            "RingSession.load() is deprecated — use "
            "RingSession.restore(path, cfg, tc, ...) instead",
            DeprecationWarning, stacklevel=2)
        return self._load_into(path)

    def _load_into(self, path: str) -> "RingSession":
        """Load a checkpoint into this (freshly created, same-config)
        session.  Raises on backend-format or policy-type mismatch instead of
        silently reinterpreting moments."""
        self.flush_metrics()               # load swaps the live buffers
        st = self.backend.state()
        params, meta = ckpt.restore(path, st["params"])
        ex = meta["extra"]
        if ex.get("format") != st["format"]:
            raise ValueError(
                f"checkpoint {path!r} was saved by a {ex.get('format')!r} "
                f"backend but this session runs {st['format']!r} — optimizer "
                f"moments are laid out per-format (stage-stacked vs full-"
                f"size) and cannot be reinterpreted across families. "
                f"Recreate the session with the saved backend.")
        saved_policy = ex.get("policy", {})
        if saved_policy.get("type") != type(self.policy).__name__:
            raise ValueError(
                f"checkpoint {path!r} was driven by policy "
                f"{saved_policy.get('type')!r} but this session has "
                f"{type(self.policy).__name__!r} — pass the matching policy "
                f"to restore() so the depth sequence continues correctly.")
        opt = ckpt.restore_opt(path, st["opt"])
        self.backend.load_state(params, opt, step=meta["step"])
        self.policy.load_state(saved_policy.get("state", {}))
        self.data.load_state(ex["data"])
        self.step_count = meta["step"]
        self._last_boundary = ex.get("last_boundary")
        return self

    @classmethod
    def restore(cls, path: str, cfg: ModelConfig, tc: TrainConfig, *,
                policy: Any = None, backend: Any = None, log=print,
                **create_kwargs) -> "RingSession":
        """Rebuild a session from a checkpoint.  Backend/shape arguments
        default to what the checkpoint recorded; the policy must be supplied
        with the same type it was saved with (its host state is restored).

        A checkpoint saved AFTER an elastic shrink records the surviving
        original-device indices; restore rebuilds the ring at the original
        size, replays the membership (shrinking away the dead stages and
        repartitioning to the saved spans) and only then loads — so the
        stage-stacked moments land on the exact geometry they were saved
        from, with no checkpoint-format special case.

        Restoring with ``elastic=True`` and ``device_profiles`` describing a
        fleet whose Algorithm-1 layout differs from the checkpoint's spans
        does not abort: the saved layout is loaded first (moments are laid
        out per span), then the ring repartitions live to the fleet's layout.
        """
        with open(path + ".json") as f:
            meta = json.load(f)
        ex = meta["extra"]
        if backend is None:
            backend = ex.get("backend", "fused")
        for k in ("n_stages", "slots_per_epoch", "cache_capacity", "impl",
                  "packed", "cache_dtype", "spans", "tenants", "elastic"):
            if k in ex and ex[k] is not None:
                create_kwargs.setdefault(k, ex[k])
        if backend == "pjit":
            # a ring checkpoint's span layout means nothing to pjit; let the
            # format-mismatch check produce the real diagnostic
            create_kwargs.pop("spans", None)
        surv = ex.get("survivors")
        saved_spans = create_kwargs.get("spans")
        if surv is not None and len(surv) < int(ex.get("n_stages") or 0):
            # post-shrink checkpoint: build at the original size with the
            # default layout (the saved spans describe the SHRUNK ring and
            # would mis-size an S0 build), then replay the membership
            create_kwargs.pop("spans", None)
            create_kwargs["elastic"] = True
        sess = cls.create(cfg, tc, backend=backend, policy=policy, log=log,
                          **create_kwargs)
        if surv is not None and len(surv) < int(ex.get("n_stages") or 0):
            sess.backend.restore_membership(surv, spans=saved_spans)
            sess._create_args["spans"] = saved_spans
            sess._create_args["survivors"] = list(surv)
        sess._load_into(path)
        if create_kwargs.get("elastic") \
                and create_kwargs.get("device_profiles") is not None:
            profs = parse_device_profiles(create_kwargs["device_profiles"])
            live = getattr(sess.backend, "spans", None)
            if live is not None and len(profs) == len(live):
                desired = [list(sp) for sp in
                           spans_from_profiles(cfg.repeats, profs)]
                if desired != [list(sp) for sp in live]:
                    log(f"[elastic] checkpoint layout "
                        f"{[e - b for b, e in live]} is stale for the given "
                        f"fleet -> repartitioning to "
                        f"{[e - b for b, e in desired]}")
                    sess.repartition(desired)
        return sess
