"""repro.api — the pluggable training facade.

``RingSession`` drives any :mod:`~repro.api.backends` adapter (reference /
fused / cached ring, pjit) under any :mod:`~repro.api.policies` unfreeze
policy (paper k-rule, explicit depths, loss-plateau-adaptive), emits
structured :class:`~repro.api.metrics.RoundMetrics`, and checkpoints the
complete resumable state.  See each module's docstring for the protocol
contracts (monotone boundary, donation, cache invalidation).
"""
from repro.core.elastic import StragglerDetector, parse_chaos_events
from repro.core.simulator import ChurnEvent

from .backends import (CachedBackend, ChaosBackend, FusedBackend, PjitBackend,
                       ReferenceBackend)
from .data import PjitDataSource, RingDataSource
from .metrics import (BenchCaptureCallback, Callback, CheckpointCallback,
                      LoggingCallback, RoundMetrics)
from .policies import (ExplicitPolicy, IntervalPolicy, LossPlateauPolicy,
                       resolve_policy)
from .session import BACKENDS, RingSession
from .tenants import AdapterStore, TenantGroup

__all__ = [
    "RingSession", "BACKENDS",
    "ReferenceBackend", "FusedBackend", "CachedBackend", "PjitBackend",
    "ChaosBackend", "ChurnEvent", "StragglerDetector", "parse_chaos_events",
    "IntervalPolicy", "ExplicitPolicy", "LossPlateauPolicy", "resolve_policy",
    "RoundMetrics", "Callback", "LoggingCallback", "CheckpointCallback",
    "BenchCaptureCallback",
    "RingDataSource", "PjitDataSource",
    "AdapterStore", "TenantGroup",
]
