"""Session data sources: checkpointable wrappers over ``repro.data.pipeline``.

A data source yields backend-shaped batches and can serialize its host-side
cursor (numpy bit-generator state + slot cursor) into JSON-able state, so a
restored session replays EXACTLY the batch sequence the interrupted run would
have seen — the piece that makes ``RingSession.save``/``restore``
bit-reproducible end to end.

Batch shapes:
  * ring backends consume ``(slot, tokens, labels)`` triples with
    tokens/labels ``[S, M, mb, seq]`` (slot is None for streaming draws);
    multi-tenant ring sessions (``tenants=T > 1``) get a tenant axis —
    ``[S, T, M, mb, seq]`` — one independent per-tenant stream per slice,
    all sharing ONE slot cursor (a joint round touches the same slot for
    every tenant, the partitioned cache's key contract);
  * the pjit backend consumes the flat dict batches of ``data.pipeline.Batcher``
    (``{"tokens", "labels"}`` or the QA ``{"tokens", "starts", "ends"}``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import (Batcher, RingBatcher, make_client_datasets,
                                 merged)

# per-tenant seed stride: tenant t draws from seed + 7919 * t (a prime far
# larger than any session count, so tenant streams never collide); tenant 0
# is the unmodified single-tenant stream — the joint-vs-independent
# differential oracle depends on both facts.
TENANT_SEED_STRIDE = 7919


class RingDataSource:
    """Per-client ring batches; slot-keyed when ``slots_per_epoch`` is set
    (the activation cache's key contract).

    ``tenants=T > 1`` stacks T independent per-tenant streams (tenant t's
    datasets AND draw order come from ``tc.seed + 7919 * t``) into
    ``[S, T, M, mb, seq]`` joint batches behind one shared slot cursor.
    ``tenant=k`` instead builds the SINGLE-tenant source that replays exactly
    tenant k's slice of the joint stream — the independent half of the
    differential oracle in tests/test_tenants.py.
    """

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, n_stages: int, *,
                 slots_per_epoch: Optional[int] = None,
                 n_per_client: int = 128, tenants: int = 1,
                 tenant: Optional[int] = None):
        if tenant is not None:
            seeds = [tc.seed + TENANT_SEED_STRIDE * tenant]
        else:
            seeds = [tc.seed + TENANT_SEED_STRIDE * t for t in range(tenants)]
        self.T = len(seeds)
        self.rbs: List[RingBatcher] = []
        for seed in seeds:
            clients = make_client_datasets(n_stages, vocab=cfg.vocab_size,
                                           n_per_client=n_per_client,
                                           seq=tc.seq_len, seed=seed)
            self.rbs.append(RingBatcher(clients, tc.n_microbatches,
                                        tc.batch_size, seed=seed,
                                        slots_per_epoch=slots_per_epoch))

    @property
    def rb(self) -> RingBatcher:          # single-tenant back-compat handle
        return self.rbs[0]

    def next(self) -> Tuple[Optional[int], Any, Any]:
        if self.rb.slots_per_epoch:
            draws = [rb.next_slot() for rb in self.rbs]
            slots = [d[0] for d in draws]
            assert len(set(slots)) == 1, slots  # one shared slot cursor
            if self.T == 1:
                return draws[0]
            return (slots[0],
                    np.stack([d[1] for d in draws], axis=1),
                    np.stack([d[2] for d in draws], axis=1))
        draws = [rb.next() for rb in self.rbs]
        if self.T == 1:
            tokens, labels = draws[0]
            return None, tokens, labels
        return (None, np.stack([d[0] for d in draws], axis=1),
                np.stack([d[1] for d in draws], axis=1))

    def state(self) -> Dict[str, Any]:
        if self.T == 1:                    # the historical checkpoint schema
            return {"rng": self.rb.rng.bit_generator.state, "t": self.rb._t}
        return {"tenants": [{"rng": rb.rng.bit_generator.state, "t": rb._t}
                            for rb in self.rbs]}

    def load_state(self, state: Dict[str, Any]) -> None:
        if "tenants" in state:
            assert len(state["tenants"]) == self.T, (len(state["tenants"]),
                                                     self.T)
            for rb, st in zip(self.rbs, state["tenants"]):
                rb.rng.bit_generator.state = st["rng"]
                rb._t = int(st["t"])
            return
        self.rb.rng.bit_generator.state = state["rng"]
        self.rb._t = int(state["t"])


class PjitDataSource:
    """Merged-client flat batches for the pjit backend (QA or LM, matching
    the config's head)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, *,
                 n_clients: int = 4, n_per_client: int = 256):
        qa = cfg.head_out == 2
        ds = merged(make_client_datasets(n_clients, vocab=cfg.vocab_size,
                                         n_per_client=n_per_client,
                                         seq=tc.seq_len, seed=tc.seed,
                                         kind="qa" if qa else "lm"))
        self.batcher = Batcher(ds, tc.batch_size, seed=tc.seed)

    def next(self) -> Dict[str, Any]:
        return self.batcher.next()

    def state(self) -> Dict[str, Any]:
        return {"rng": self.batcher.rng.bit_generator.state}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.batcher.rng.bit_generator.state = state["rng"]
