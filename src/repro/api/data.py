"""Session data sources: checkpointable wrappers over ``repro.data.pipeline``.

A data source yields backend-shaped batches and can serialize its host-side
cursor (numpy bit-generator state + slot cursor) into JSON-able state, so a
restored session replays EXACTLY the batch sequence the interrupted run would
have seen — the piece that makes ``RingSession.save``/``restore``
bit-reproducible end to end.

Batch shapes:
  * ring backends consume ``(slot, tokens, labels)`` triples with
    tokens/labels ``[S, M, mb, seq]`` (slot is None for streaming draws);
  * the pjit backend consumes the flat dict batches of ``data.pipeline.Batcher``
    (``{"tokens", "labels"}`` or the QA ``{"tokens", "starts", "ends"}``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import (Batcher, RingBatcher, make_client_datasets,
                                 merged)


class RingDataSource:
    """Per-client ring batches; slot-keyed when ``slots_per_epoch`` is set
    (the activation cache's key contract)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, n_stages: int, *,
                 slots_per_epoch: Optional[int] = None, n_per_client: int = 128):
        clients = make_client_datasets(n_stages, vocab=cfg.vocab_size,
                                       n_per_client=n_per_client,
                                       seq=tc.seq_len, seed=tc.seed)
        self.rb = RingBatcher(clients, tc.n_microbatches, tc.batch_size,
                              seed=tc.seed, slots_per_epoch=slots_per_epoch)

    def next(self) -> Tuple[Optional[int], Any, Any]:
        if self.rb.slots_per_epoch:
            return self.rb.next_slot()
        tokens, labels = self.rb.next()
        return None, tokens, labels

    def state(self) -> Dict[str, Any]:
        return {"rng": self.rb.rng.bit_generator.state, "t": self.rb._t}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.rb.rng.bit_generator.state = state["rng"]
        self.rb._t = int(state["t"])


class PjitDataSource:
    """Merged-client flat batches for the pjit backend (QA or LM, matching
    the config's head)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, *,
                 n_clients: int = 4, n_per_client: int = 256):
        qa = cfg.head_out == 2
        ds = merged(make_client_datasets(n_clients, vocab=cfg.vocab_size,
                                         n_per_client=n_per_client,
                                         seq=tc.seq_len, seed=tc.seed,
                                         kind="qa" if qa else "lm"))
        self.batcher = Batcher(ds, tc.batch_size, seed=tc.seed)

    def next(self) -> Dict[str, Any]:
        return self.batcher.next()

    def state(self) -> Dict[str, Any]:
        return {"rng": self.batcher.rng.bit_generator.state}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.batcher.rng.bit_generator.state = state["rng"]
