"""Multi-tenant personalization surface: AdapterStore + TenantGroup.

One frozen trunk, T adapter sets per ring: a multi-tenant ``RingSession``
(``tenants=T``) trains T per-tenant adapter+head sets in one joint conveyor.
This module is the unit of *exchange* around that loop:

  * :class:`AdapterStore` — a directory of named adapter bundles.  Each entry
    is one tenant's complete trainable set (``{"adapter": [R, ...] tree,
    "head": head tree}``) persisted through ``checkpoint.save`` — the Adam
    moments ride along under the existing ``opt::`` key namespace, so a
    bundle is fully resumable, a few MB even for a 7B trunk.  The store is
    the hand-off point between training and serving: ``launch/serve.py``'s
    registry watches entry mtimes and hot-swaps freshly trained adapters
    into the running batcher without a restart (the S-LoRA pattern: one
    shared trunk in memory, adapters grafted per request).
  * :class:`TenantGroup` — one tenant's view of a live session: per-tenant
    loss out of the joint round metrics, per-tenant cache hit accounting,
    and ``save_to``/``load_from`` that move exactly that tenant's adapters +
    moments through a store (loading flushes ONLY that tenant's cache
    partition — neighbors keep their entries).
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import checkpoint as ckpt

BUNDLE_FORMAT = "AdapterStore/v1"


class AdapterStore:
    """Directory-backed store of named adapter bundles.

    Layout: ``<root>/<name>.npz`` + ``<root>/<name>.json`` per entry
    (checkpoint module format; optimizer moments under ``opt::`` keys).
    Names are path fragments — keep them to ``[A-Za-z0-9_.-]``.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        if os.sep in name or name.startswith("."):
            raise ValueError(f"bundle name {name!r} must be a plain filename")
        return os.path.join(self.root, name)

    def names(self) -> List[str]:
        return sorted(f[:-5] for f in os.listdir(self.root)
                      if f.endswith(".json"))

    def __contains__(self, name: str) -> bool:
        return os.path.exists(self._path(name) + ".json")

    def mtime(self, name: str) -> float:
        """Payload mtime — the serve registry's staleness probe."""
        return os.path.getmtime(self._path(name) + ".npz")

    def put(self, name: str, bundle: Dict[str, Any], *,
            opt: Any = None, step: int = 0,
            meta: Optional[Dict[str, Any]] = None) -> None:
        """Persist one tenant's ``{"adapter", "head"}`` bundle (+ optional
        per-tenant Adam moments under ``opt::``).  Atomic enough for the
        serve-side mtime watch: the .npz lands before the .json that
        announces it."""
        if set(bundle) != {"adapter", "head"}:
            raise ValueError(
                f"a bundle has exactly the keys {{'adapter', 'head'}} "
                f"(RingExecutor.export_adapters's layout), got "
                f"{sorted(bundle)}")
        ckpt.save(self._path(name), bundle, step=step, opt_state=opt,
                  extra={"format": BUNDLE_FORMAT, **(meta or {})})

    def get(self, name: str, like: Dict[str, Any]
            ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Load a bundle into the structure/shapes of ``like`` (use the live
        ``export_adapters()`` tree).  Returns ``(bundle, meta)``."""
        bundle, meta = ckpt.restore(self._path(name), like)
        fmt = meta.get("extra", {}).get("format")
        if fmt != BUNDLE_FORMAT:
            raise ValueError(
                f"{self._path(name)!r} is not an adapter bundle "
                f"(format={fmt!r}); AdapterStore only reads entries it wrote")
        return bundle, meta

    def get_opt(self, name: str, like: Any) -> Any:
        """Load a bundle's Adam moments (``opt::`` namespace; raises if the
        bundle was saved without them)."""
        return ckpt.restore_opt(self._path(name), like)

    def has_opt(self, name: str) -> bool:
        import json
        with open(self._path(name) + ".json") as f:
            return bool(json.load(f).get("has_opt_state"))


class TenantGroup:
    """One tenant's handle on a live multi-tenant session.

    Obtained from ``RingSession.tenants`` — never constructed directly.
    All methods address tenant ``self.index`` of the session's executor;
    ``load_from`` invalidates only this tenant's cache partition.
    """

    def __init__(self, session, index: int):
        self.session = session
        self.index = index

    def __repr__(self) -> str:
        return (f"TenantGroup({self.index} of "
                f"{getattr(self.session.backend, 'T', 1)})")

    @property
    def _driver(self):
        d = getattr(self.session.backend, "driver", None)
        if d is None or not hasattr(d, "export_adapters"):
            raise NotImplementedError(
                f"backend {self.session.backend.name!r} has no per-tenant "
                f"adapter surface")
        return d

    # -- metrics --------------------------------------------------------
    def metrics(self, m) -> Dict[str, Any]:
        """This tenant's slice of a (materialized) RoundMetrics: its own
        loss out of the joint round, plus its cache hit/miss counters."""
        out = {"step": m.step, "boundary": m.boundary, "depth": m.depth,
               "tenant": self.index}
        tl = m.extras.get("tenant_losses")
        out["loss"] = tl[self.index] if tl is not None else m.loss
        if m.cache and "tenant_cache_hits" in m.cache:
            out["cache_hits"] = m.cache["tenant_cache_hits"][self.index]
            out["cache_misses"] = m.cache["tenant_cache_misses"][self.index]
        return out

    # -- adapters + moments ---------------------------------------------
    def export_adapters(self) -> Dict[str, Any]:
        return self._driver.export_adapters(self.index)

    def export_opt(self) -> Dict[str, Any]:
        return self._driver.export_tenant_opt(self.index)

    def save_to(self, store: AdapterStore, name: str, *,
                with_opt: bool = True,
                meta: Optional[Dict[str, Any]] = None) -> None:
        """Persist this tenant's adapters (+ moments under ``opt::``) as a
        named store entry — immediately servable by a watching registry."""
        store.put(name, self.export_adapters(),
                  opt=self.export_opt() if with_opt else None,
                  step=self.session.step_count,
                  meta={"tenant": self.index, **(meta or {})})

    def load_from(self, store: AdapterStore, name: str, *,
                  with_opt: bool = True) -> None:
        """Install a store entry into this tenant's slot.  Flushes only this
        tenant's ``(tenant, slot, boundary)`` cache partition; the other
        tenants' entries (and hit-rates) are untouched."""
        bundle, _ = store.get(name, self.export_adapters())
        self._driver.import_adapters(self.index, bundle)
        if with_opt and store.has_opt(name):
            self._driver.import_tenant_opt(
                self.index, store.get_opt(name, self.export_opt()))
