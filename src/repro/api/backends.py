"""Backend adapters: one ``step`` protocol over every training path.

The seed repo grew four divergent drivers — the unfused ``RingTrainer``
oracle, the fused ``RingExecutor``, the executor + ``ActivationCache``
combination, and the pjit staged-recompile loop — each hand-wired in
``launch/train.py``.  A :class:`Backend` adapts each one to a single surface
the :class:`~repro.api.session.RingSession` can drive:

    class Backend(Protocol):
        kind: str                 # "ring" | "pjit" (selects the data source)
        name: str                 # CLI/back-compat name
        steps_per_call: int       # global steps one step() advances
        compile_count: int        # executables built so far
        @classmethod
        def build(cls, cfg, tc, policy, *, n_stages, spans, device_profiles,
                  params, slots_per_epoch, cache_capacity, packed,
                  cache_dtype, impl, tenants, log) -> Backend
        def step(self, batch) -> dict           # raw metrics (may hold device arrays)
        def state(self) -> dict                 # {"format", "params", "opt"}
        def load_state(self, params, opt, *, step) -> None
        def export_params(self) -> params tree  # canonical [R, ...] layout

    ``build`` is the one constructor the session calls: every backend takes
    the SAME keyword surface and validates/ignores what it doesn't support
    (pjit rejects spans, reference/pjit reject tenants > 1, cached requires
    ``slots_per_epoch``), so ``RingSession.create`` is a single dispatch
    instead of a per-backend kwarg ladder.

Protocol contracts every adapter honors:

  * **monotone boundary** — the backend evaluates its (injected) policy's
    ``depth_at`` per step/round; the resulting boundary may never increase
    (re-checked here and in ``core/executor.py``);
  * **donation** — fused/pjit steps donate params + optimizer moments, so a
    caller must treat the trees it handed in as consumed; ``state()`` always
    returns the LIVE trees;
  * **cache invalidation** — the cached backend's activation cache is keyed
    ``(slot, boundary)`` and cleared wholesale on every boundary drop and on
    ``load_state`` (a restored session never serves pre-restore activations).

``state()["format"]`` tags the optimizer-state layout (ring moments are
stage-stacked ``[S, lps, ...]``; pjit moments are full-size ``[R, ...]`` per
pattern entry).  Checkpoints restore only into a backend with the same
format — the session raises a clear error instead of silently reshaping
moments across families.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pipeline as pl
from repro.core import training
from repro.core.elastic import StragglerDetector
from repro.core.partition import (DeviceProfile, parse_device_profiles,
                                  span_sizes, spans_from_profiles,
                                  uniform_assignment)
from repro.core.simulator import ChurnEvent
from repro.core.unfreeze import depth_to_boundary
from repro.models import params as prm
from repro.optim import adamw

CACHE_STAT_KEYS = ("cache_hits", "cache_misses", "cache_hit_rate",
                   "cache_evictions", "cache_invalidations", "cache_bypasses",
                   "cache_entries", "cache_capacity", "cache_dtype",
                   "cache_bytes_per_entry", "cache_buffer_bytes")


def _default_params(cfg: ModelConfig, tc: TrainConfig):
    return prm.materialize(prm.param_defs(cfg), jax.random.key(tc.seed),
                           cfg.dtype)


def _validate_ring(cfg: ModelConfig, n_stages: int) -> None:
    """The ring-mode preconditions that used to live in launch/train.py.

    (The historical repeats-divisible-by-stages precondition is gone: the
    ragged-span pipeline runs any contiguous layout, and ``spans=None``
    falls back to the most balanced split.)
    """
    if cfg.head_out is not None:
        raise ValueError(
            f"ring backends train with the LM objective, but this config has "
            f"a task head (head_out={cfg.head_out}) — the loss would be "
            f"garbage/NaN. Use an LM config, or reduce with head_out=None "
            f"like examples/ring_finetune.py.")
    if cfg.repeats < n_stages:
        raise ValueError(
            f"ring training needs at least one block per stage: "
            f"cfg.repeats={cfg.repeats} < n_stages={n_stages}.")


def _block_weight_mb(cfg: ModelConfig) -> float:
    """Per-block weight footprint (MB) — the memory cost Algorithm 1 charges
    a device per assigned block when DeviceProfile budgets are finite."""
    kind = cfg.pattern[0][0]
    n = prm.count_params(prm.block_defs(cfg, kind)) * cfg.layers_per_repeat
    return n * jnp.dtype(cfg.dtype).itemsize / 2**20


def _resolve_ring_spans(cfg: ModelConfig, n_stages: int, spans,
                        device_profiles):
    """(spans, device_profiles) -> canonical span layout (None = balanced).

    ``device_profiles`` (speeds or DeviceProfile objects, ring order) runs
    the paper's Algorithm-1 speed-weighted assignment; an explicit ``spans``
    ([(b, e)] pairs or a sizes list like [4, 5, 2, 3]) wins over both.
    Profiles with FINITE ``memory_mb`` budgets also bind the assignment's
    memory-feasibility constraint, charged at the per-block weight footprint
    (bare speeds — the CLI path — leave memory unconstrained).
    """
    if spans is None and device_profiles is not None:
        import math

        profiles = parse_device_profiles(device_profiles)
        if len(profiles) != n_stages:
            raise ValueError(
                f"{len(profiles)} device profiles for a {n_stages}-stage "
                f"ring — pass exactly one per stage, in ring order")
        mem = None
        if any(math.isfinite(p.memory_mb) for p in profiles):
            mem = [_block_weight_mb(cfg)] * cfg.repeats
        spans = spans_from_profiles(cfg.repeats, profiles, layer_mem_mb=mem)
    return pl.resolve_spans(cfg.repeats, n_stages, spans)


class _RingBackendBase:
    """Shared plumbing for the three ring adapters (mesh, batch unpacking,
    canonical <-> stage-stacked param translation, opt-state format tag,
    span-layout resolution)."""

    kind = "ring"
    T = 1                                  # tenants (multi-tenant overrides)

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, policy, *,
                 n_stages: int, params: Optional[Dict[str, Any]] = None,
                 spans=None, device_profiles=None):
        from repro.launch.mesh import make_ring_mesh, require_devices

        _validate_ring(cfg, n_stages)
        require_devices(n_stages)
        self.cfg, self.tc, self.policy = cfg, tc, policy
        self.S = n_stages
        self.spans = _resolve_ring_spans(cfg, n_stages, spans,
                                         device_profiles)
        self.mesh = make_ring_mesh(n_stages)
        self._init_params = params if params is not None else _default_params(cfg, tc)

    # -- shared surface -------------------------------------------------
    @property
    def steps_per_call(self) -> int:
        return self.S                      # one round = S initiator steps

    @property
    def format(self) -> str:
        """Opt-state layout tag.  Non-default span layouts are part of the
        format: adapter moments are padded [S, max_span, ...] per the layout,
        so a checkpoint only restores into the same layout.  Multi-tenant
        sessions append ``/T{T}`` — tenant-stacked moments ([S, T, ...]) are
        a different layout family from single-tenant ones."""
        default = tuple(uniform_assignment(self.cfg.repeats, self.S))
        if self.spans == default:
            tag = f"ring/S{self.S}"
        else:
            sig = "-".join(str(n) for n in span_sizes(self.spans))
            tag = f"ring/S{self.S}/spans{sig}"
        return tag if self.T == 1 else f"{tag}/T{self.T}"

    def export_params(self) -> Dict[str, Any]:
        return self.driver.export_params()

    @staticmethod
    def _unpack(batch) -> Tuple[Optional[int], Any, Any]:
        if len(batch) == 3:
            return batch
        tokens, labels = batch
        return None, tokens, labels

    def _depth_of(self, boundary: int) -> int:
        return self.cfg.repeats - boundary

    def _restack(self, params: Dict[str, Any]) -> None:
        d = self.driver
        if hasattr(d, "load_canonical"):
            # the executor owns its canonical <-> stacked translation (and at
            # T > 1 the tree is tenant-stacked — only it knows that layout)
            d.load_canonical(params)
            return
        d.stage_blocks, d.shared = pl.stage_stack(params, self.cfg, self.S,
                                                  spans=self.spans)
        d._params_rest = {k: v for k, v in params.items() if k != "blocks"}

    def repartition(self, spans) -> None:
        """Switch the live span layout (executor-backed backends only); the
        session flushes pending device metrics before calling this."""
        d = self.driver
        if not hasattr(d, "repartition"):
            raise NotImplementedError(
                f"backend {self.name!r} cannot repartition mid-run")
        d.repartition(pl.resolve_spans(self.cfg.repeats, self.S, spans))
        self.spans = d.spans

    def shrink(self, dead_stage: int, *, spans=None, profiles=None) -> None:
        """Live S -> S-1 shrink (executor-backed backends only): drop stage
        ``dead_stage`` and reassign its span over the survivors.  The caller
        flushes pending device metrics first — the restack donates the
        buffers they point at."""
        d = self.driver
        if not hasattr(d, "shrink"):
            raise NotImplementedError(
                f"backend {self.name!r} cannot shrink mid-run — use "
                f"backend='fused' or 'cached'")
        d.shrink(dead_stage, spans=spans, profiles=profiles)
        self.S, self.mesh, self.spans = d.S, d.mesh, d.spans

    def grow(self, profile=None, *, spans=None, profiles=None) -> None:
        """Inverse of ``shrink``: a device joins, S grows by one."""
        d = self.driver
        if not hasattr(d, "grow"):
            raise NotImplementedError(
                f"backend {self.name!r} cannot grow mid-run — use "
                f"backend='fused' or 'cached'")
        d.grow(profile, spans=spans, profiles=profiles)
        self.S, self.mesh, self.spans = d.S, d.mesh, d.spans


class ReferenceBackend(_RingBackendBase):
    """The unfused ``RingTrainer`` oracle: S dispatches per round, host-side
    optimizer, one loss sync per iteration (metrics are host floats)."""

    name = "reference"

    def __init__(self, cfg, tc, policy, *, n_stages: int, params=None,
                 spans=None, device_profiles=None):
        from repro.core.ring import RingTrainer

        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params,
                         spans=spans, device_profiles=device_profiles)
        self.driver = RingTrainer(cfg, tc, self.mesh, self._init_params,
                                  n_stages, tc.n_microbatches, schedule=policy,
                                  spans=self.spans)

    @classmethod
    def build(cls, cfg, tc, policy, *, n_stages, spans=None,
              device_profiles=None, params=None, slots_per_epoch=None,
              cache_capacity=None, packed=True, cache_dtype="native",
              impl="jnp", tenants=1, log=print) -> "ReferenceBackend":
        if tenants > 1:
            raise ValueError(
                "tenants > 1 needs the fused executable (tenant-stacked "
                "adapters + the T-tenant conveyor) — use backend='fused' or "
                "'cached'; the reference oracle is single-tenant")
        return cls(cfg, tc, policy, n_stages=n_stages, params=params,
                   spans=spans, device_profiles=device_profiles)

    @property
    def compile_count(self) -> int:
        return self.driver.n_executables

    def step(self, batch) -> Dict[str, Any]:
        _, tokens, labels = self._unpack(batch)
        with compat.set_mesh(self.mesh):
            m = self.driver.round(tokens, labels)
        return {"loss": m["loss"], "boundary": m["boundary"],
                "depth": self._depth_of(m["boundary"]), "step": m["step"],
                "tokens": int(tokens.size)}

    def state(self) -> Dict[str, Any]:
        d = self.driver
        opt = {"m": {"adapter": d.m_ad, "head": d.m_hd},
               "v": {"adapter": d.v_ad, "head": d.v_hd},
               "count": jnp.int32(d.step)}
        return {"format": self.format, "params": self.export_params(),
                "opt": opt}

    def load_state(self, params, opt, *, step: int) -> None:
        self._restack(params)
        d = self.driver
        d.m_ad, d.m_hd = opt["m"]["adapter"], opt["m"]["head"]
        d.v_ad, d.v_hd = opt["v"]["adapter"], opt["v"]["head"]
        d.step = step


class FusedBackend(_RingBackendBase):
    """The fused ``RingExecutor``: one donated executable per boundary,
    metrics stay on device until the session materializes them."""

    name = "fused"

    def __init__(self, cfg, tc, policy, *, n_stages: int, params=None,
                 cache_capacity: int = 0, packed: bool = True,
                 cache_dtype: str = "native", spans=None,
                 device_profiles=None, tenants: int = 1):
        from repro.core.executor import RingExecutor

        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params,
                         spans=spans, device_profiles=device_profiles)
        self.T = tenants
        self.driver = RingExecutor(cfg, tc, self.mesh, self._init_params,
                                   n_stages, tc.n_microbatches,
                                   cache_capacity=cache_capacity,
                                   schedule=policy, packed=packed,
                                   cache_dtype=cache_dtype, spans=self.spans,
                                   tenants=tenants)

    @classmethod
    def build(cls, cfg, tc, policy, *, n_stages, spans=None,
              device_profiles=None, params=None, slots_per_epoch=None,
              cache_capacity=None, packed=True, cache_dtype="native",
              impl="jnp", tenants=1, log=print) -> "FusedBackend":
        return cls(cfg, tc, policy, n_stages=n_stages, params=params,
                   packed=packed, cache_dtype=cache_dtype, spans=spans,
                   device_profiles=device_profiles, tenants=tenants)

    @property
    def compile_count(self) -> int:
        return self.driver.n_executables

    def step(self, batch) -> Dict[str, Any]:
        slot, tokens, labels = self._unpack(batch)
        with compat.set_mesh(self.mesh):
            m = self.driver.round(tokens, labels, slot=slot)
        raw = {"loss": m["loss"], "boundary": m["boundary"],
               "depth": self._depth_of(m["boundary"]), "step": m["step"],
               "tokens": int(tokens.size),
               "extras": {"losses": m["losses"]}}
        if self.T > 1:
            raw["extras"]["tenant_losses"] = m["tenant_losses"]
        if self.driver.cache is not None:
            raw["cache"] = {k: m[k] for k in CACHE_STAT_KEYS}
            raw["cache_hit"] = m["cache_hit"]
            if self.T > 1:
                raw["cache"]["tenant_cache_hits"] = m["tenant_cache_hits"]
                raw["cache"]["tenant_cache_misses"] = m["tenant_cache_misses"]
        return raw

    def state(self) -> Dict[str, Any]:
        return {"format": self.format, "params": self.export_params(),
                "opt": self.driver.opt_state}

    def load_state(self, params, opt, *, step: int) -> None:
        self._restack(params)
        d = self.driver
        d.opt_state = opt
        d.step = step
        d._last_boundary = None            # monotone check re-seeds post-load
        if d.cache is not None:
            d.cache.invalidate()           # never serve pre-restore activations


class CachedBackend(FusedBackend):
    """Fused executor + the frozen-trunk activation cache (Phase-A skip).

    Requires slot-keyed batches (``slots_per_epoch`` on the data source) —
    streaming draws would never revisit a key, so constructing this backend
    without a positive capacity is an error rather than a silent no-op.
    """

    name = "cached"

    def __init__(self, cfg, tc, policy, *, n_stages: int, cache_capacity: int,
                 params=None, packed: bool = True,
                 cache_dtype: str = "native", spans=None,
                 device_profiles=None, tenants: int = 1):
        if cache_capacity < 1:
            raise ValueError(
                f"CachedBackend needs cache_capacity >= 1 (got "
                f"{cache_capacity}); use FusedBackend for uncached rounds")
        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params,
                         cache_capacity=cache_capacity, packed=packed,
                         cache_dtype=cache_dtype, spans=spans,
                         device_profiles=device_profiles, tenants=tenants)

    @classmethod
    def build(cls, cfg, tc, policy, *, n_stages, spans=None,
              device_profiles=None, params=None, slots_per_epoch=None,
              cache_capacity=None, packed=True, cache_dtype="native",
              impl="jnp", tenants=1, log=print) -> "CachedBackend":
        if not slots_per_epoch:
            raise ValueError(
                "backend='cached' needs slots_per_epoch >= 1: the "
                "activation cache keys on stable batch slots — with "
                "streaming draws no key ever repeats. Use "
                "backend='fused' for non-repeating data.")
        cap = (cache_capacity if cache_capacity is not None
               else slots_per_epoch * tenants)
        # T tenants each own a (tenant, slot, boundary) key per slot, so the
        # thrash threshold scales with T as well.
        if 0 < cap < slots_per_epoch * tenants:
            # round-robin slots + LRU: every slot is evicted before its
            # revisit — all capture cost, zero hits
            log(f"WARNING: cache_capacity {cap} < slots_per_epoch "
                f"{slots_per_epoch}"
                + (f" x tenants {tenants}" if tenants > 1 else "")
                + ": the cache will thrash (0% hits, capture overhead every "
                  "round) — raise the capacity or use backend='fused'")
        return cls(cfg, tc, policy, n_stages=n_stages, cache_capacity=cap,
                   params=params, packed=packed, cache_dtype=cache_dtype,
                   spans=spans, device_profiles=device_profiles,
                   tenants=tenants)


class PjitBackend:
    """The staged-recompile pjit path: single- or multi-device data/tensor
    parallel steps, one jitted+donated step fn per distinct boundary."""

    kind = "pjit"
    name = "pjit"
    steps_per_call = 1

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, policy, *,
                 impl: str = "jnp", params: Optional[Dict[str, Any]] = None):
        self.cfg, self.tc, self.policy = cfg, tc, policy
        self.impl = impl
        self._params = params if params is not None else _default_params(cfg, tc)
        self._opt = adamw.init(training.full_trainable(self._params))
        self._fns: Dict[int, Any] = {}      # boundary -> jitted step
        self._step = 0

    @classmethod
    def build(cls, cfg, tc, policy, *, n_stages=None, spans=None,
              device_profiles=None, params=None, slots_per_epoch=None,
              cache_capacity=None, packed=True, cache_dtype="native",
              impl="jnp", tenants=1, log=print) -> "PjitBackend":
        if spans is not None or device_profiles is not None:
            raise ValueError(
                "spans/device_profiles describe the ring's stage layout "
                "— they have no meaning for the pjit backend")
        if tenants > 1:
            raise ValueError(
                "tenants > 1 is a ring concept (T adapter sets over one "
                "frozen ring trunk) — use backend='fused' or 'cached'")
        return cls(cfg, tc, policy, impl=impl, params=params)

    @property
    def format(self) -> str:
        return "pjit"

    @property
    def compile_count(self) -> int:
        return len(self._fns)

    def _fn(self, boundary: int):
        if boundary not in self._fns:
            fn = training.make_step(self.cfg, self.tc, boundary,
                                    impl=self.impl)
            self._fns[boundary] = jax.jit(fn, donate_argnums=(0, 1))
        return self._fns[boundary]

    def step(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        depth = self.policy.depth_at(self._step, self.cfg.n_layers)
        boundary = depth_to_boundary(self.cfg, depth)
        self._params, self._opt, metrics = self._fn(boundary)(
            self._params, self._opt, batch)
        self._step += 1
        extras = {k: v for k, v in metrics.items() if k != "loss"}
        return {"loss": metrics["loss"], "boundary": boundary, "depth": depth,
                "step": self._step, "tokens": int(batch["tokens"].size),
                "extras": extras}

    def export_params(self) -> Dict[str, Any]:
        return self._params

    def state(self) -> Dict[str, Any]:
        return {"format": self.format, "params": self._params,
                "opt": self._opt}

    def load_state(self, params, opt, *, step: int) -> None:
        self._params = params
        self._opt = opt
        self._step = step


class ChaosBackend:
    """Fault-injection + elasticity wrapper over a ring backend.

    Wraps any executor-backed ring backend and, per ``step``:

      1. fires every pending :class:`~repro.core.simulator.ChurnEvent` whose
         round has arrived (``round=3`` means rounds 0-2 ran on the old
         fleet) — a ``crash``/``leave`` shrinks the inner ring live (with
         ``elastic=True``; without it the crash raises, which is exactly
         what the un-wrapped ring would do by stalling), a ``slowdown``
         degrades that device's ground-truth speed, a ``join`` reclaims a
         previously-dead device's slot;
      2. trims the round's ``[S0, ...]`` batch to the survivors' original
         rows (the data source keeps producing at the original ring size,
         which is what makes save -> resume bit-reproducible across a
         shrink);
      3. delegates to the inner backend;
      4. synthesizes per-stage wall times from the ground-truth speeds
         (``span_size / speed`` — the SPMD tick model; real deployments
         would use measured stage timings) into ``extras["stage_times"]``;
      5. with ``elastic=True``, feeds those timings to a
         :class:`~repro.core.elastic.StragglerDetector` and applies its
         (hysteresis-gated) repartition proposal.

    Any round that changed the ring layout is flagged
    ``raw["layout_changed"]`` so the session can re-seed its monotone-
    boundary check and suspend plateau policies for the blip.  Everything
    else (``state``/``load_state``/``format``/``export_params``/...)
    delegates to the inner backend untouched.
    """

    def __init__(self, inner, *, events: Sequence[ChurnEvent] = (),
                 elastic: bool = False, device_profiles=None, log=print):
        self.inner = inner
        self.elastic = elastic
        self.log = log
        self.events: List[ChurnEvent] = sorted(events, key=lambda e: e.round)
        if device_profiles is not None:
            profs = parse_device_profiles(device_profiles)
            if len(profs) != inner.S:
                raise ValueError(
                    f"{len(profs)} device profiles for a {inner.S}-stage "
                    f"ring")
        else:
            profs = [DeviceProfile(1.0, float("inf"))
                     for _ in range(inner.S)]
        # keyed by ORIGINAL device index — survivors map stage -> original
        self.profiles: Dict[int, DeviceProfile] = dict(enumerate(profs))
        self.speeds: Dict[int, float] = {
            i: p.compute_speed for i, p in self.profiles.items()}
        self.survivors: List[int] = list(range(inner.S))
        self.detector: Optional[StragglerDetector] = (
            StragglerDetector(profs, inner.cfg.repeats) if elastic else None)
        self.flush_hook = None              # session assigns: flush metrics
        self.round_idx = 0
        self.shrinks = 0
        self.repartitions = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _flush(self) -> None:
        if self.flush_hook is not None:
            self.flush_hook()

    def _survivor_profiles(self) -> List[DeviceProfile]:
        if self.detector is not None:
            return self.detector.fleet      # EWMA-refit speeds
        return [self.profiles[d] for d in self.survivors]

    def _apply(self, ev: ChurnEvent) -> bool:
        """Fire one event against the live ring; True if the layout moved."""
        if ev.kind in ("crash", "leave"):
            if ev.device not in self.survivors:
                raise ValueError(
                    f"churn {ev.kind} targets device {ev.device}, which is "
                    f"not alive (survivors: {self.survivors})")
            if not self.elastic:
                raise RuntimeError(
                    f"device {ev.device} {'crashed' if ev.kind == 'crash' else 'left'} "
                    f"at round {self.round_idx} and the ring is not elastic "
                    f"— run with elastic=True (--elastic) to shrink and "
                    f"continue")
            stage = self.survivors.index(ev.device)
            self._flush()
            self.survivors.pop(stage)
            if self.detector is not None:
                self.detector.remove(stage)
            old = [list(sp) for sp in self.inner.spans]
            self.inner.shrink(stage, profiles=self._survivor_profiles())
            self.shrinks += 1
            self.log(f"[elastic] device {ev.device} {ev.kind} at round "
                     f"{self.round_idx}: ring {len(self.survivors) + 1} -> "
                     f"{len(self.survivors)} stages, spans {old} -> "
                     f"{[list(sp) for sp in self.inner.spans]} "
                     f"(cache re-captures next round)")
            return True
        if ev.kind == "slowdown":
            if ev.device not in self.survivors:
                raise ValueError(
                    f"churn slowdown targets device {ev.device}, which is "
                    f"not alive (survivors: {self.survivors})")
            self.speeds[ev.device] /= ev.factor
            self.log(f"[elastic] device {ev.device} slowed {ev.factor}x at "
                     f"round {self.round_idx}"
                     + ("" if self.elastic else
                        " (not elastic: the ring will limp, not repartition)"))
            return False                    # detector discovers it from timings
        # join: only a previously-dead device's slot can be reclaimed — the
        # data source still owns exactly S0 rows, so a genuinely new device
        # would have no data stream to serve.
        if ev.device in self.survivors:
            raise ValueError(f"churn join: device {ev.device} is already "
                             f"in the ring")
        if ev.device not in self.profiles:
            raise ValueError(
                f"churn join: device {ev.device} was never part of the "
                f"original fleet — only rejoining devices are supported "
                f"(the data source owns the original rows)")
        if not self.elastic:
            raise RuntimeError(
                f"device {ev.device} rejoined at round {self.round_idx} and "
                f"the ring is not elastic — run with elastic=True (--elastic)")
        prof = ev.profile or self.profiles[ev.device]
        stage = sum(1 for d in self.survivors if d < ev.device)
        self._flush()
        self.survivors.insert(stage, ev.device)
        if self.detector is not None:
            self.detector.insert(stage, prof)
        self.inner.grow(profiles=self._survivor_profiles())
        self.log(f"[elastic] device {ev.device} rejoined at round "
                 f"{self.round_idx}: ring {len(self.survivors) - 1} -> "
                 f"{len(self.survivors)} stages, spans "
                 f"{[list(sp) for sp in self.inner.spans]}")
        return True

    def step(self, batch) -> Dict[str, Any]:
        layout_changed = False
        while self.events and self.events[0].round <= self.round_idx:
            layout_changed |= self._apply(self.events.pop(0))
        if len(self.survivors) != len(self.profiles):
            rows = np.asarray(self.survivors)
            if len(batch) == 3:
                slot, tokens, labels = batch
                batch = (slot, tokens[rows], labels[rows])
            else:
                tokens, labels = batch
                batch = (tokens[rows], labels[rows])
        raw = self.inner.step(batch)
        stage_times = [(e - b) / self.speeds[dev] for (b, e), dev
                       in zip(self.inner.spans, self.survivors)]
        extras = raw.setdefault("extras", {})
        extras["stage_times"] = stage_times
        extras["survivors"] = list(self.survivors)
        if self.detector is not None:
            self.detector.observe(self.inner.spans, stage_times)
            prop = self.detector.propose(self.inner.spans)
            if prop is not None:
                self._flush()
                old = [list(sp) for sp in self.inner.spans]
                self.inner.repartition(prop)
                self.repartitions += 1
                layout_changed = True
                self.log(f"[elastic] straggler repartition at round "
                         f"{self.round_idx}: spans {old} -> "
                         f"{[list(sp) for sp in self.inner.spans]} "
                         f"(EWMA speeds "
                         f"{[round(s, 3) for s in self.detector.speeds]})")
        if layout_changed:
            raw["layout_changed"] = True
            extras["layout_changed"] = True
        self.round_idx += 1
        return raw

    def restore_membership(self, survivors: Sequence[int],
                           spans=None) -> None:
        """Replay a checkpoint's saved fleet state onto a freshly-built
        full-size ring: shrink away every device missing from ``survivors``
        (in stage order), then repartition to the exact saved ``spans`` —
        run BEFORE ``load_state`` so the stage-stacked moments land on the
        right geometry."""
        for dead in [d for d in list(self.survivors) if d not in survivors]:
            stage = self.survivors.index(dead)
            self.survivors.pop(stage)
            if self.detector is not None:
                self.detector.remove(stage)
            self.inner.shrink(stage, profiles=self._survivor_profiles())
            self.shrinks += 1
        if list(survivors) != self.survivors:
            raise ValueError(
                f"saved survivors {list(survivors)} are not a subset of the "
                f"original fleet {sorted(self.profiles)}")
        if spans is not None:
            self.inner.repartition(spans)
