"""Backend adapters: one ``step`` protocol over every training path.

The seed repo grew four divergent drivers — the unfused ``RingTrainer``
oracle, the fused ``RingExecutor``, the executor + ``ActivationCache``
combination, and the pjit staged-recompile loop — each hand-wired in
``launch/train.py``.  A :class:`Backend` adapts each one to a single surface
the :class:`~repro.api.session.RingSession` can drive:

    class Backend(Protocol):
        kind: str                 # "ring" | "pjit" (selects the data source)
        name: str                 # CLI/back-compat name
        steps_per_call: int       # global steps one step() advances
        compile_count: int        # executables built so far
        def step(self, batch) -> dict           # raw metrics (may hold device arrays)
        def state(self) -> dict                 # {"format", "params", "opt"}
        def load_state(self, params, opt, *, step) -> None
        def export_params(self) -> params tree  # canonical [R, ...] layout

Protocol contracts every adapter honors:

  * **monotone boundary** — the backend evaluates its (injected) policy's
    ``depth_at`` per step/round; the resulting boundary may never increase
    (re-checked here and in ``core/executor.py``);
  * **donation** — fused/pjit steps donate params + optimizer moments, so a
    caller must treat the trees it handed in as consumed; ``state()`` always
    returns the LIVE trees;
  * **cache invalidation** — the cached backend's activation cache is keyed
    ``(slot, boundary)`` and cleared wholesale on every boundary drop and on
    ``load_state`` (a restored session never serves pre-restore activations).

``state()["format"]`` tags the optimizer-state layout (ring moments are
stage-stacked ``[S, lps, ...]``; pjit moments are full-size ``[R, ...]`` per
pattern entry).  Checkpoints restore only into a backend with the same
format — the session raises a clear error instead of silently reshaping
moments across families.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pipeline as pl
from repro.core import training
from repro.core.unfreeze import depth_to_boundary
from repro.models import params as prm
from repro.optim import adamw

CACHE_STAT_KEYS = ("cache_hits", "cache_misses", "cache_hit_rate",
                   "cache_evictions", "cache_invalidations", "cache_bypasses",
                   "cache_entries", "cache_capacity", "cache_dtype",
                   "cache_bytes_per_entry", "cache_buffer_bytes")


def _default_params(cfg: ModelConfig, tc: TrainConfig):
    return prm.materialize(prm.param_defs(cfg), jax.random.key(tc.seed),
                           cfg.dtype)


def _validate_ring(cfg: ModelConfig, n_stages: int) -> None:
    """The ring-mode preconditions that used to live in launch/train.py."""
    if cfg.head_out is not None:
        raise ValueError(
            f"ring backends train with the LM objective, but this config has "
            f"a task head (head_out={cfg.head_out}) — the loss would be "
            f"garbage/NaN. Use an LM config, or reduce with head_out=None "
            f"like examples/ring_finetune.py.")
    if cfg.repeats % n_stages != 0:
        raise ValueError(
            f"ring training needs repeats divisible by stages: "
            f"cfg.repeats={cfg.repeats}, n_stages={n_stages}. Pick n_stages "
            f"from the divisors of {cfg.repeats}, or a config/reduced "
            f"variant with more repeats.")


class _RingBackendBase:
    """Shared plumbing for the three ring adapters (mesh, batch unpacking,
    canonical <-> stage-stacked param translation, opt-state format tag)."""

    kind = "ring"

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, policy, *,
                 n_stages: int, params: Optional[Dict[str, Any]] = None):
        from repro.launch.mesh import make_ring_mesh, require_devices

        _validate_ring(cfg, n_stages)
        require_devices(n_stages)
        self.cfg, self.tc, self.policy = cfg, tc, policy
        self.S = n_stages
        self.mesh = make_ring_mesh(n_stages)
        self._init_params = params if params is not None else _default_params(cfg, tc)

    # -- shared surface -------------------------------------------------
    @property
    def steps_per_call(self) -> int:
        return self.S                      # one round = S initiator steps

    @property
    def format(self) -> str:
        return f"ring/S{self.S}"

    def export_params(self) -> Dict[str, Any]:
        return self.driver.export_params()

    @staticmethod
    def _unpack(batch) -> Tuple[Optional[int], Any, Any]:
        if len(batch) == 3:
            return batch
        tokens, labels = batch
        return None, tokens, labels

    def _depth_of(self, boundary: int) -> int:
        return self.cfg.repeats - boundary

    def _restack(self, params: Dict[str, Any]) -> None:
        d = self.driver
        d.stage_blocks, d.shared = pl.stage_stack(params, self.cfg, self.S)
        d._params_rest = {k: v for k, v in params.items() if k != "blocks"}


class ReferenceBackend(_RingBackendBase):
    """The unfused ``RingTrainer`` oracle: S dispatches per round, host-side
    optimizer, one loss sync per iteration (metrics are host floats)."""

    name = "reference"

    def __init__(self, cfg, tc, policy, *, n_stages: int, params=None):
        from repro.core.ring import RingTrainer

        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params)
        self.driver = RingTrainer(cfg, tc, self.mesh, self._init_params,
                                  n_stages, tc.n_microbatches, schedule=policy)

    @property
    def compile_count(self) -> int:
        return self.driver.n_executables

    def step(self, batch) -> Dict[str, Any]:
        _, tokens, labels = self._unpack(batch)
        with compat.set_mesh(self.mesh):
            m = self.driver.round(tokens, labels)
        return {"loss": m["loss"], "boundary": m["boundary"],
                "depth": self._depth_of(m["boundary"]), "step": m["step"],
                "tokens": int(tokens.size)}

    def state(self) -> Dict[str, Any]:
        d = self.driver
        opt = {"m": {"adapter": d.m_ad, "head": d.m_hd},
               "v": {"adapter": d.v_ad, "head": d.v_hd},
               "count": jnp.int32(d.step)}
        return {"format": self.format, "params": self.export_params(),
                "opt": opt}

    def load_state(self, params, opt, *, step: int) -> None:
        self._restack(params)
        d = self.driver
        d.m_ad, d.m_hd = opt["m"]["adapter"], opt["m"]["head"]
        d.v_ad, d.v_hd = opt["v"]["adapter"], opt["v"]["head"]
        d.step = step


class FusedBackend(_RingBackendBase):
    """The fused ``RingExecutor``: one donated executable per boundary,
    metrics stay on device until the session materializes them."""

    name = "fused"

    def __init__(self, cfg, tc, policy, *, n_stages: int, params=None,
                 cache_capacity: int = 0, packed: bool = True,
                 cache_dtype: str = "native"):
        from repro.core.executor import RingExecutor

        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params)
        self.driver = RingExecutor(cfg, tc, self.mesh, self._init_params,
                                   n_stages, tc.n_microbatches,
                                   cache_capacity=cache_capacity,
                                   schedule=policy, packed=packed,
                                   cache_dtype=cache_dtype)

    @property
    def compile_count(self) -> int:
        return self.driver.n_executables

    def step(self, batch) -> Dict[str, Any]:
        slot, tokens, labels = self._unpack(batch)
        with compat.set_mesh(self.mesh):
            m = self.driver.round(tokens, labels, slot=slot)
        raw = {"loss": m["loss"], "boundary": m["boundary"],
               "depth": self._depth_of(m["boundary"]), "step": m["step"],
               "tokens": int(tokens.size),
               "extras": {"losses": m["losses"]}}
        if self.driver.cache is not None:
            raw["cache"] = {k: m[k] for k in CACHE_STAT_KEYS}
            raw["cache_hit"] = m["cache_hit"]
        return raw

    def state(self) -> Dict[str, Any]:
        return {"format": self.format, "params": self.export_params(),
                "opt": self.driver.opt_state}

    def load_state(self, params, opt, *, step: int) -> None:
        self._restack(params)
        d = self.driver
        d.opt_state = opt
        d.step = step
        d._last_boundary = None            # monotone check re-seeds post-load
        if d.cache is not None:
            d.cache.invalidate()           # never serve pre-restore activations


class CachedBackend(FusedBackend):
    """Fused executor + the frozen-trunk activation cache (Phase-A skip).

    Requires slot-keyed batches (``slots_per_epoch`` on the data source) —
    streaming draws would never revisit a key, so constructing this backend
    without a positive capacity is an error rather than a silent no-op.
    """

    name = "cached"

    def __init__(self, cfg, tc, policy, *, n_stages: int, cache_capacity: int,
                 params=None, packed: bool = True,
                 cache_dtype: str = "native"):
        if cache_capacity < 1:
            raise ValueError(
                f"CachedBackend needs cache_capacity >= 1 (got "
                f"{cache_capacity}); use FusedBackend for uncached rounds")
        super().__init__(cfg, tc, policy, n_stages=n_stages, params=params,
                         cache_capacity=cache_capacity, packed=packed,
                         cache_dtype=cache_dtype)


class PjitBackend:
    """The staged-recompile pjit path: single- or multi-device data/tensor
    parallel steps, one jitted+donated step fn per distinct boundary."""

    kind = "pjit"
    name = "pjit"
    steps_per_call = 1

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, policy, *,
                 impl: str = "jnp", params: Optional[Dict[str, Any]] = None):
        self.cfg, self.tc, self.policy = cfg, tc, policy
        self.impl = impl
        self._params = params if params is not None else _default_params(cfg, tc)
        self._opt = adamw.init(training.full_trainable(self._params))
        self._fns: Dict[int, Any] = {}      # boundary -> jitted step
        self._step = 0

    @property
    def format(self) -> str:
        return "pjit"

    @property
    def compile_count(self) -> int:
        return len(self._fns)

    def _fn(self, boundary: int):
        if boundary not in self._fns:
            fn = training.make_step(self.cfg, self.tc, boundary,
                                    impl=self.impl)
            self._fns[boundary] = jax.jit(fn, donate_argnums=(0, 1))
        return self._fns[boundary]

    def step(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        depth = self.policy.depth_at(self._step, self.cfg.n_layers)
        boundary = depth_to_boundary(self.cfg, depth)
        self._params, self._opt, metrics = self._fn(boundary)(
            self._params, self._opt, batch)
        self._step += 1
        extras = {k: v for k, v in metrics.items() if k != "loss"}
        return {"loss": metrics["loss"], "boundary": boundary, "depth": depth,
                "step": self._step, "tokens": int(batch["tokens"].size),
                "extras": extras}

    def export_params(self) -> Dict[str, Any]:
        return self._params

    def state(self) -> Dict[str, Any]:
        return {"format": self.format, "params": self._params,
                "opt": self._opt}

    def load_state(self, params, opt, *, step: int) -> None:
        self._params = params
        self._opt = opt
        self._step = step
