"""RoundMetrics + the session callback hooks.

``RoundMetrics`` is the structured record one ``RingSession.step`` returns.
Scalar fields that come out of a fused executor round are DEVICE arrays until
``materialize()`` is called — the session materializes in batches (once per
logging interval), preserving the executor's async-dispatch contract: holding
an unmaterialized RoundMetrics never forces a host sync.

Callbacks observe *materialized* metrics only, so a callback can never
accidentally sync the device mid-interval.  The hook points:

    on_start(session)            before the first step of ``run``
    on_round(session, metrics)   once per step, at materialization time
    on_end(session, history)     after the last step (history = list of dicts)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.executor import scalarize as _scalarize


@dataclass(eq=False)                       # identity hash: the session tracks
class RoundMetrics:                        # live instances in a WeakSet
    """One training step/round, structured.

    ``loss`` (and ``extras`` values) may be device arrays before
    ``materialize()``; every other field is host-side from birth.
    """

    step: int                          # global step AFTER this round
    boundary: int                      # frozen repeats from the bottom
    depth: int                         # unfrozen blocks from the top
    loss: Any                          # scalar (device array until materialized)
    compile_count: int = 0             # executables built so far (cumulative)
    tokens: int = 0                    # tokens consumed by this round
    tokens_per_sec: Optional[float] = None   # filled at materialization
    wall_s: Optional[float] = None           # since run() start
    cache: Optional[Dict[str, float]] = None  # actcache stats, if caching
    cache_hit: Optional[bool] = None
    extras: Dict[str, Any] = field(default_factory=dict)
    materialized: bool = False

    def materialize(self, *, wall_s: Optional[float] = None,
                    tokens_per_sec: Optional[float] = None) -> "RoundMetrics":
        """Host-sync every device value -> a new, fully-scalar RoundMetrics."""
        if self.materialized:
            # already scalar (e.g. a loss-driven policy synced early): just
            # fill in the timing fields the flush supplies
            return dataclasses.replace(
                self,
                wall_s=self.wall_s if wall_s is None else wall_s,
                tokens_per_sec=(self.tokens_per_sec if tokens_per_sec is None
                                else tokens_per_sec))
        return dataclasses.replace(
            self, loss=_scalarize(self.loss),
            extras={k: _scalarize(v) for k, v in self.extras.items()},
            wall_s=self.wall_s if wall_s is None else wall_s,
            tokens_per_sec=(self.tokens_per_sec if tokens_per_sec is None
                            else tokens_per_sec),
            materialized=True)

    def flush_(self) -> "RoundMetrics":
        """Host-sync IN PLACE (``materialize`` returns a copy; this mutates).

        The session calls this on every outstanding metric before a
        donation-invalidating backend call (``repartition``, checkpoint
        load): a lazy device value read after its buffers were donated away
        would be garbage.  Idempotent; timing fields are left for the run
        loop's flush to fill."""
        if not self.materialized:
            self.loss = _scalarize(self.loss)
            self.extras = {k: _scalarize(v) for k, v in self.extras.items()}
            self.materialized = True
        return self

    def to_dict(self) -> Dict[str, Any]:
        """Flat history dict (the shape ``launch/train.py`` always logged):
        loss/boundary/step/depth/wall_s at the top, cache stats as cache_*,
        extras merged in."""
        assert self.materialized, "materialize() before to_dict()"
        out = {"loss": self.loss, "boundary": self.boundary,
               "step": self.step, "depth": self.depth}
        if self.wall_s is not None:
            out["wall_s"] = self.wall_s
        if self.tokens_per_sec is not None:
            out["tokens_per_sec"] = round(self.tokens_per_sec, 2)
        out["compile_count"] = self.compile_count
        if self.cache is not None:
            out.update(self.cache)
            out["cache_hit"] = self.cache_hit
        out.update(self.extras)
        return out


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------


class Callback:
    """Base class: override any subset of the hooks."""

    def on_start(self, session) -> None:
        pass

    def on_round(self, session, metrics: RoundMetrics) -> None:
        pass

    def on_end(self, session, history: List[Dict[str, Any]]) -> None:
        pass


class LoggingCallback(Callback):
    """Per-interval progress lines, plus a guaranteed final-state line (the
    cadence follows materialization batches, so fused async behavior is
    preserved)."""

    def __init__(self, log=print, every: int = 1):
        self.log = log
        self.every = max(every, 1)
        self._n = 0
        self._last_step: Optional[int] = None

    def _emit(self, d: Dict[str, Any]) -> None:
        self._last_step = d["step"]
        cache = ""
        if "cache_hit_rate" in d:
            cache = (f" cache[hit={d['cache_hit_rate']:.0%} "
                     f"inval={d['cache_invalidations']:.0f}]")
        acc = d.get("accuracy", d.get("f1"))
        acc = "" if acc is None else f" acc/f1={acc:.3f}"
        tps = d.get("tokens_per_sec")
        tps = "" if tps is None else f" {tps:,.0f} tok/s"
        # a round that shrank/grew/repartitioned the ring gets a marker so
        # the loss blip right after it reads as recovery, not divergence
        el = ""
        if d.get("layout_changed"):
            surv = d.get("survivors")
            el = (" [elastic]" if surv is None
                  else f" [elastic S={len(surv)}]")
        self.log(f"step {d['step']:5d} b={d['boundary']:2d} "
                 f"d={d['depth']:2d} loss={d['loss']:.4f}"
                 f"{acc}{cache}{tps}{el} ({d.get('wall_s')}s)")

    def on_round(self, session, m: RoundMetrics) -> None:
        self._n += 1
        if (self._n - 1) % self.every == 0:
            self._emit(m.to_dict())

    def on_end(self, session, history) -> None:
        # the run's final state always gets a line, aligned interval or not
        if history and history[-1]["step"] != self._last_step:
            self._emit(history[-1])


class CheckpointCallback(Callback):
    """``session.save(path)`` every N observed rounds (and at on_end).

    Rounds are observed at materialization time, so the effective checkpoint
    granularity is bounded below by ``run``'s ``log_every`` — and the state
    saved is the session's CURRENT state (a flush delivering many rounds at
    once produces ONE save, not one per round)."""

    def __init__(self, path: str, every: int = 50):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.path = path
        self.every = every
        self._n = 0
        self._saved_at: Optional[int] = None

    def _save_once(self, session) -> None:
        if session.step_count != self._saved_at:
            session.save(self.path)
            self._saved_at = session.step_count

    def on_round(self, session, m: RoundMetrics) -> None:
        self._n += 1
        if self._n % self.every == 0:
            self._save_once(session)

    def on_end(self, session, history) -> None:
        self._save_once(session)


class BenchCaptureCallback(Callback):
    """Captures the perf trajectory (loss / tokens-per-sec / compile counts /
    cache hit rate per round) for benchmark harnesses."""

    def __init__(self):
        self.rounds: List[Dict[str, Any]] = []

    def on_round(self, session, m: RoundMetrics) -> None:
        self.rounds.append(m.to_dict())

    def result(self) -> Dict[str, Any]:
        if not self.rounds:
            return {}
        last = self.rounds[-1]
        tps = [r["tokens_per_sec"] for r in self.rounds
               if r.get("tokens_per_sec")]
        out = {"rounds": len(self.rounds),
               "final_loss": last["loss"],
               "final_boundary": last["boundary"],
               "compile_count": last["compile_count"],
               "boundary_trace": [r["boundary"] for r in self.rounds]}
        if tps:
            out["tokens_per_sec_steady"] = tps[-1]
        if "cache_hit_rate" in last:
            out["cache_hit_rate"] = last["cache_hit_rate"]
        return out
