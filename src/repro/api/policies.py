"""Pluggable unfreeze policies — WHO decides the depth, decoupled from HOW.

The paper's Algorithm 1 hard-wires one rule (depth += 1 every ``k`` steps).
``repro.api`` turns the rule into a protocol so a session can swap it without
touching any driver:

    class UnfreezePolicy(Protocol):
        wants_loss: bool
        def depth_at(self, step: int, n_blocks: int) -> int: ...
        def observe(self, step: int, loss: float) -> None: ...
        def state(self) -> dict: ...            # checkpointable host state
        def load_state(self, state: dict) -> None: ...

**The monotone-boundary contract** (the one rule every policy MUST obey):
``depth_at`` may never return a smaller depth than it returned for an earlier
step — equivalently the unfreeze boundary may never increase.  RingAda
unfreezes top-down only, and the frozen-trunk activation cache
(``core/actcache.py``) invalidates wholesale on boundary *drops*; a boundary
that could rise again would serve stale trunk activations.  The policies here
are monotone by construction, and the contract is still re-checked at runtime
by ``RingSession`` and by ``core/executor.py`` — a policy that violates it
fails loudly, never silently.

``depth_at`` is HOST-side and cheap (called once per step/round, outside jit);
depth changes surface as staged recompiles, exactly like the seed's schedule.

Loss-driven policies set ``wants_loss = True``: the session then materializes
the loss every round and calls ``observe`` (one host sync per round — the
price of adaptivity; interval policies keep the fused executor's async
dispatch intact).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from repro.configs.base import TrainConfig
from repro.core.unfreeze import UnfreezeSchedule


class IntervalPolicy:
    """The paper's k-step rule: depth = initial + step // interval (capped).

    Stateless (depth is a pure function of the step counter), so checkpoint
    resume is trivially bit-reproducible.
    """

    wants_loss = False

    def __init__(self, initial_depth: int = 1, interval: int = 40,
                 max_depth: Optional[int] = None):
        self._sched = UnfreezeSchedule(initial_depth=initial_depth,
                                       interval=interval, max_depth=max_depth)

    @staticmethod
    def from_train_config(tc: TrainConfig) -> "IntervalPolicy":
        return IntervalPolicy(initial_depth=tc.initial_unfreeze_depth,
                              interval=tc.unfreeze_interval,
                              max_depth=tc.max_unfreeze_depth)

    def depth_at(self, step: int, n_blocks: int) -> int:
        return self._sched.depth_at(step, n_blocks)

    def observe(self, step: int, loss: float) -> None:
        pass

    def state(self) -> Dict:
        return {}

    def load_state(self, state: Dict) -> None:
        pass

    def __repr__(self):
        s = self._sched
        return (f"IntervalPolicy(initial_depth={s.initial_depth}, "
                f"interval={s.interval}, max_depth={s.max_depth})")


class ExplicitPolicy:
    """An explicit per-segment depths tuple (segment i = steps [i*k, (i+1)*k)).

    Non-monotone tuples are rejected at construction by
    ``core/unfreeze.py``'s ``UnfreezeSchedule`` — the contract holds before a
    single step runs.  ``ExplicitPolicy((n_blocks,))`` is the "all hot from
    step 0" baseline (PipeAdapter/Single-style).
    """

    wants_loss = False

    def __init__(self, depths: Tuple[int, ...], interval: int = 40,
                 max_depth: Optional[int] = None):
        self._sched = UnfreezeSchedule(interval=interval, depths=tuple(depths),
                                       max_depth=max_depth)

    def depth_at(self, step: int, n_blocks: int) -> int:
        return self._sched.depth_at(step, n_blocks)

    def observe(self, step: int, loss: float) -> None:
        pass

    def state(self) -> Dict:
        return {}

    def load_state(self, state: Dict) -> None:
        pass

    def __repr__(self):
        return (f"ExplicitPolicy(depths={self._sched.depths}, "
                f"interval={self._sched.interval})")


class LossPlateauPolicy:
    """Adaptive unfreezing: open the next adapter when the loss plateaus.

    Keeps an exponential moving average of the observed loss; when the EMA
    fails to improve on its best value by at least ``min_rel_improve``
    (relatively) for ``patience`` consecutive observations, the depth is
    bumped by one and the plateau detector resets.  In the spirit of
    dynamic-chain edge adaptation (Beyond End-to-End, arXiv:2604.06819): the
    schedule reacts to training progress instead of a fixed step count.

    Monotone by construction: ``_depth`` is only ever incremented, so the
    boundary can only fall — the activation-cache invalidation contract holds
    for ANY loss sequence, including adversarial ones (oscillating, rising,
    NaN/inf).  Non-finite losses never corrupt the EMA; they count as
    "no improvement" observations (a diverging run unfreezes more capacity
    rather than wedging the detector).

    ``min_wait`` rate-limits unfreezes (at most one per ``min_wait``
    observations) so a cliff-shaped loss curve cannot unfreeze the whole
    stack in a burst of consecutive plateau detections.
    """

    wants_loss = True

    def __init__(self, initial_depth: int = 1, patience: int = 3,
                 min_rel_improve: float = 1e-3, smoothing: float = 0.6,
                 max_depth: Optional[int] = None, min_wait: int = 1):
        if initial_depth < 1:
            raise ValueError(f"initial_depth must be >= 1, got {initial_depth}")
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        if not (0.0 <= smoothing < 1.0):
            raise ValueError(f"smoothing must be in [0, 1), got {smoothing}")
        self.patience = patience
        self.min_rel_improve = min_rel_improve
        self.smoothing = smoothing
        self.max_depth = max_depth
        self.min_wait = max(min_wait, 1)
        self._depth = initial_depth
        self._ema: Optional[float] = None
        self._best: Optional[float] = None
        self._bad = 0                    # consecutive no-improvement count
        self._since_unfreeze = 0         # observations since the last bump
        self._suspended = 0              # observations to skip (recovery blips)

    def depth_at(self, step: int, n_blocks: int) -> int:
        cap = min(self.max_depth or n_blocks, n_blocks)
        return min(self._depth, cap)

    def suspend(self, rounds: int = 1) -> None:
        """Skip the next ``rounds`` observations.  The session calls this
        after an elastic layout change: a recovery round's loss blip (new
        span alignment, re-captured cache) is a geometry artifact, not
        plateau evidence — counting it would bias the unfreeze schedule."""
        self._suspended = max(self._suspended, int(rounds))

    def observe(self, step: int, loss: float) -> None:
        if self._suspended > 0:
            self._suspended -= 1
            return
        self._since_unfreeze += 1
        if loss is not None and math.isfinite(loss):
            self._ema = (loss if self._ema is None
                         else self.smoothing * self._ema
                         + (1.0 - self.smoothing) * loss)
            if (self._best is None
                    or self._ema < self._best * (1.0 - self.min_rel_improve)):
                self._best = self._ema
                self._bad = 0
                return
        # non-finite loss, or EMA failed to beat the best: one plateau tick
        self._bad += 1
        if self._bad >= self.patience and self._since_unfreeze >= self.min_wait:
            self._depth += 1             # monotone: only ever increments
            self._bad = 0
            self._since_unfreeze = 0
            self._best = self._ema       # plateau restarts from current level

    def state(self) -> Dict:
        return {"depth": self._depth, "ema": self._ema, "best": self._best,
                "bad": self._bad, "since_unfreeze": self._since_unfreeze,
                "suspended": self._suspended}

    def load_state(self, state: Dict) -> None:
        self._depth = int(state["depth"])
        self._ema = state["ema"]
        self._best = state["best"]
        self._bad = int(state["bad"])
        self._since_unfreeze = int(state["since_unfreeze"])
        # pre-elastic checkpoints have no "suspended" key
        self._suspended = int(state.get("suspended", 0))

    def __repr__(self):
        return (f"LossPlateauPolicy(depth={self._depth}, "
                f"patience={self.patience}, "
                f"min_rel_improve={self.min_rel_improve})")


def resolve_policy(policy, tc: TrainConfig):
    """None -> the paper's rule from tc; strings -> named defaults."""
    if policy is None or policy == "interval":
        return IntervalPolicy.from_train_config(tc)
    if policy == "plateau":
        return LossPlateauPolicy(initial_depth=tc.initial_unfreeze_depth,
                                 max_depth=tc.max_unfreeze_depth)
    if isinstance(policy, str):
        raise ValueError(f"unknown policy {policy!r}; use 'interval', "
                         f"'plateau', or an UnfreezePolicy instance")
    return policy
